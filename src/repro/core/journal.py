"""Shared JSONL-journal primitives: ONE flock/fsync code path for every
on-disk record stream in the system.

Every persistent artifact the search stack writes is the same shape — an
append-only JSONL file that concurrent writers (threads *and* processes)
share, readers load tolerantly (torn trailing lines are skipped), and a
bounded compaction rewrites atomically so a long-lived service can't grow
it without limit.  That idiom grew up independently in the seed bank,
``search_meta.jsonl``, ``surrogate_fit.jsonl`` and the measurement cache;
this module hoists it so all of them — and the plan-service's
:class:`~repro.service.store.PlanStore` — serialize on the identical
sidecar-flock/fsync path instead of five hand-rolled copies.

Invariants every user relies on:

* **appends are atomic-enough**: writers serialize on the ``.lock``
  sidecar (advisory ``flock``; in-process threads serialize on it too
  because each acquisition opens its own descriptor), so a line is never
  interleaved with another writer's;
* **reads never lock**: a reader may observe a torn trailing line from a
  concurrent append — :meth:`Journal.records` skips it;
* **compaction is atomic**: rewrite to ``.tmp`` + ``fsync`` +
  ``os.replace`` under the lock, so a concurrent append can't vanish
  mid-compaction and a crash can't leave a half-written journal;
* **durability is opt-in**: ``fsync=True`` (the plan store) forces every
  append to disk before returning; the measurement journals keep the OS
  page cache's timing (losing a measurement re-measures, losing a
  deployed plan re-searches — only the latter justifies the fsync cost).
"""
from __future__ import annotations

import contextlib
import json
import os
from typing import Any, Callable, Iterable, Optional, Sequence

__all__ = ["file_lock", "Journal", "newest_per_key"]


@contextlib.contextmanager
def file_lock(lock_path: str):
    """Exclusive advisory lock on a sidecar file; no-op where fcntl is
    unavailable.  Not reentrant — never nest acquisitions of the same
    sidecar (two descriptors of one process conflict under ``flock``)."""
    try:
        import fcntl
    except ImportError:
        yield
        return
    with open(lock_path, "w") as lf:
        fcntl.flock(lf, fcntl.LOCK_EX)
        try:
            yield
        finally:
            fcntl.flock(lf, fcntl.LOCK_UN)


class Journal:
    """One append-only JSONL file with locked writes, tolerant reads, and
    atomic bounded compaction — the storage cell every persistent record
    stream (seed bank, search meta, surrogate fits, measurements, plan
    store) is built from."""

    def __init__(self, path: str, fsync: bool = False):
        self.path = path
        self.lock_path = path + ".lock"
        self.fsync = bool(fsync)
        parent = os.path.dirname(path)
        if parent:
            os.makedirs(parent, exist_ok=True)

    def lock(self):
        """The journal's write lock (see :func:`file_lock`; not reentrant —
        use the ``locked=False`` method variants inside)."""
        return file_lock(self.lock_path)

    # -- writes -------------------------------------------------------------

    def append(self, recs: Sequence[dict], locked: bool = True) -> None:
        ctx = self.lock() if locked else contextlib.nullcontext()
        with ctx:
            with open(self.path, "a", encoding="utf-8") as f:
                for rec in recs:
                    f.write(json.dumps(rec) + "\n")
                if self.fsync:
                    f.flush()
                    os.fsync(f.fileno())

    def rewrite(self, recs: Iterable[dict], locked: bool = True) -> None:
        """Atomically replace the journal's contents (tmp + fsync +
        ``os.replace``).  Callers already holding :meth:`lock` must pass
        ``locked=False``."""
        ctx = self.lock() if locked else contextlib.nullcontext()
        with ctx:
            tmp = self.path + ".tmp"
            with open(tmp, "w", encoding="utf-8") as f:
                for rec in recs:
                    f.write(json.dumps(rec) + "\n")
                f.flush()
                os.fsync(f.fileno())
            os.replace(tmp, self.path)

    # -- reads (lock-free) --------------------------------------------------

    def records(self) -> list[dict]:
        """Every parseable record, file order.  Torn trailing lines from a
        concurrent append and non-dict lines are skipped."""
        out: list[dict] = []
        try:
            with open(self.path, "r", encoding="utf-8") as f:
                for line in f:
                    line = line.strip()
                    if not line:
                        continue
                    try:
                        rec = json.loads(line)
                    except json.JSONDecodeError:
                        continue  # torn concurrent write; journal append-only
                    if isinstance(rec, dict):
                        out.append(rec)
        except FileNotFoundError:
            pass
        return out

    def line_count(self) -> int:
        try:
            with open(self.path, "r", encoding="utf-8") as f:
                return sum(1 for _ in f)
        except FileNotFoundError:
            return 0

    # -- bounded compaction --------------------------------------------------

    def compact(self, keep: Callable[[list[dict]], list[dict]],
                threshold: Optional[int] = None) -> bool:
        """Rewrite the journal to ``keep(records)`` when it has outgrown
        ``threshold`` lines (always, when ``threshold`` is None).  The
        records are re-read *under the lock* so a concurrent append can't
        land between read and replace.  Returns True when a rewrite
        happened."""
        if threshold is not None and self.line_count() <= threshold:
            return False
        with self.lock():
            self.rewrite(keep(self.records()), locked=False)
        return True


def newest_per_key(recs: Sequence[dict], key: Callable[[dict], Any],
                   max_records: Optional[int] = None,
                   per_key: int = 1) -> list[dict]:
    """The shared compaction policy: collapse to the newest ``per_key``
    records per key (line order = recency order), keep the overall newest
    ``max_records``, preserving last-occurrence order.  Records whose key
    is falsy are dropped (unparseable/foreign lines)."""
    by_key: dict[Any, list[dict]] = {}
    for rec in recs:
        k = key(rec)
        if not k:
            continue
        kept = by_key.pop(k, [])
        kept.append(rec)
        by_key[k] = kept[-max(1, int(per_key)):]  # reinsert: recency order
    out = [rec for kept in by_key.values() for rec in kept]
    if max_records is not None:
        out = out[-int(max_records):]
    return out
