"""Frontend-neutral variant resolution: one resolve / check / fallback rule.

The paper's final step — replace a matched block with a library
implementation, verify the converted program, fall back when the
replacement does not apply — is the same step in every source language.
This module is that step factored out of the jaxpr substitution engine so
all frontends share it:

  * :func:`resolve_variant` — the resolution rule: a requested
    implementation id (a reference alias, a concrete variant name, or the
    legacy ``"kernel"``/``"auto"`` preference order) is bound against a
    :class:`~repro.kernels.registry.CallSite` through the kernel registry's
    availability predicates, with an abstract-eval output check
    (:func:`check_adapter`); any rejection degrades to the reference path
    with the reason preserved.
  * :class:`SubstitutionChoice` / :class:`SubstitutionReport` — the uniform
    record of what ran where.  Every frontend's plan produces one (the
    jaxpr engine and the ast executor from real resolution, the module /
    ir frontends via :func:`generic_plan_report`), so
    ``OffloadResult.report`` has the same shape whatever the source
    language.

:mod:`repro.core.substitution` (the jaxpr engine) and
:mod:`repro.core.frontends.ast_frontend` both resolve through here.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Callable, Optional

import jax

from repro.kernels.registry import (CallSite, KernelRegistry,
                                    VariantUnavailable, auto_variant_order,
                                    default_registry)
from repro.obs import metrics as obs_metrics

__all__ = ["SubstitutionChoice", "SubstitutionReport", "check_adapter",
           "resolve_variant", "generic_plan_report"]


#: implementation ids that mean "the reference path" in any frontend.
_REF_IMPLS = frozenset({"ref", "interp", "host", "cpu"})
#: implementation ids that mean "pick the backend-preferred variant".
_AUTO_IMPLS = frozenset({"kernel", "offload", "auto"})


# ---------------------------------------------------------------------------
# the uniform report
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class SubstitutionChoice:
    """What happened at one substitutable region."""

    region: str
    pattern: Optional[str]
    requested: str                    # the impl the plan asked for
    chosen: str                       # "ref" or the bound implementation id
    why: str = ""                     # fallback / resolution reason


@dataclass
class SubstitutionReport:
    choices: list[SubstitutionChoice] = field(default_factory=list)

    @property
    def substituted(self) -> dict[str, str]:
        """region -> implementation for every region not on the ref path."""
        return {c.region: c.chosen for c in self.choices if c.chosen != "ref"}

    @property
    def fallbacks(self) -> dict[str, str]:
        """region -> reason for every request the plan had to refuse."""
        return {c.region: c.why for c in self.choices
                if c.chosen == "ref" and c.requested not in _REF_IMPLS}

    def summary(self) -> dict:
        return {"substituted": self.substituted, "fallbacks": self.fallbacks}


# ---------------------------------------------------------------------------
# resolution
# ---------------------------------------------------------------------------


def check_adapter(adapter: Callable, site: CallSite) -> None:
    """Abstract-evaluate the adapter and require aval-exact outputs for
    every used output (None stands for an output the variant skips)."""
    specs = [jax.ShapeDtypeStruct(a.shape, a.dtype) for a in site.in_avals]
    try:
        outs = jax.eval_shape(lambda *xs: adapter(*xs), *specs)
    except Exception as e:  # noqa: BLE001 — adapter bug == unavailable
        raise VariantUnavailable(f"adapter failed abstract eval: "
                                 f"{type(e).__name__}: {e}") from None
    outs = tuple(outs) if isinstance(outs, (tuple, list)) else (outs,)
    if len(outs) != len(site.out_avals):
        raise VariantUnavailable(
            f"adapter returned {len(outs)} outputs, site has "
            f"{len(site.out_avals)}")
    for i, (got, want, used) in enumerate(
            zip(outs, site.out_avals, site.out_used)):
        if got is None:
            if used:
                raise VariantUnavailable(
                    f"output {i} is used but the variant skips it")
            continue
        if tuple(got.shape) != tuple(want.shape) \
                or got.dtype != want.dtype:
            raise VariantUnavailable(
                f"output {i} aval mismatch: {got.shape}/{got.dtype} vs "
                f"{want.shape}/{want.dtype}")


def resolve_variant(site: CallSite, requested: str,
                    registry: Optional[KernelRegistry] = None,
                    backend: Optional[str] = None,
                    check: bool = True
                    ) -> tuple[Optional[Callable], str, str]:
    """Resolve one site's requested implementation.

    Returns ``(adapter or None, chosen name, why)``: the bound adapter and
    its variant name on success, ``(None, "ref", reason)`` for a reference
    request, an unknown id, an unmatched site, or a predicate/output-check
    rejection — the shared fallback rule every frontend applies.
    """
    registry = registry or default_registry()
    backend = backend or jax.default_backend()

    def _count(outcome: str, variant: str) -> None:
        # bind/fallback telemetry tagged by pattern and variant — the live
        # counterpart of the pattern_precision journal (repro.core.pattern_db)
        obs_metrics.counter("variants.resolutions",
                            pattern=site.pattern or "-",
                            variant=variant, outcome=outcome).inc()

    if requested in _REF_IMPLS:
        return None, "ref", "requested"
    if not site.pattern:
        _count("no_pattern", str(requested))
        return None, "ref", "no pattern matched this region"
    names = registry.variant_names(site.pattern)
    if requested in names:
        candidates = (requested,)
    elif requested in _AUTO_IMPLS:
        candidates = tuple(n for n in auto_variant_order(backend)
                           if n in names) or names
    else:
        _count("unknown", str(requested))
        return None, "ref", f"unknown implementation {requested!r}"
    why = ""
    for name in candidates:
        try:
            adapter = registry.get(site.pattern, name).bind(site)
            if check:
                check_adapter(adapter, site)
            _count("bound", name)
            return adapter, name, ""
        except VariantUnavailable as e:
            why = f"{name}: {e}"
    _count("fallback", str(requested))
    return None, "ref", why


# ---------------------------------------------------------------------------
# the generic report (frontends without their own resolution step)
# ---------------------------------------------------------------------------


def generic_plan_report(coding, values, base_impl: Optional[dict] = None,
                        patterns: Optional[dict] = None) -> SubstitutionReport:
    """Uniform :class:`SubstitutionReport` straight from the gene decode.

    For frontends whose implementations are plain ids with no binding step
    (module ExecPlan values, ir impl maps): one choice per gene site —
    reference-decoding genes report ``ref``, cost-only destinations report
    the destination name falling back to ``ref``, clamped ``impl_index``
    records the clamp — plus one choice per block-pass claim.
    """
    from repro.core.genes import get_destination

    patterns = patterns or {}
    report = SubstitutionReport()
    for s, v in zip(coding.sites, tuple(values)):
        dest = get_destination(coding.destinations[int(v)])
        impls = s.impls
        impl = impls[min(dest.impl_index, len(impls) - 1)]
        requested, why = str(impl), ""
        if dest.impl_index >= len(impls):
            why = (f"impl_index {dest.impl_index} clamped to {impl!r} "
                   f"({len(impls)} impls)")
        is_ref = impl == s.ref_impl or str(impl) in _REF_IMPLS
        if dest.placement_tag is not None:
            # stub devices and mesh placements: the decode is the reference
            # path, the destination name is what the gene actually chose
            requested = dest.name
            why = (f"cost-only destination {dest.name!r} runs the reference "
                   f"path" if dest.is_cost_only else
                   f"mesh destination {dest.name!r} (sharded execution is "
                   f"the frontend's to realize)")
        elif is_ref:
            requested, why = "ref", why or "requested"
        report.choices.append(SubstitutionChoice(
            s.region, patterns.get(s.region), requested,
            "ref" if is_ref else str(impl), why))
    for region, impl in sorted((base_impl or {}).items()):
        impl = str(impl)
        report.choices.append(SubstitutionChoice(
            region, patterns.get(region), impl,
            "ref" if impl in _REF_IMPLS else impl, "block-pass claim"))
    return report
