"""Roofline analysis from compiled XLA artifacts (TPU v5e targets).

This is the "verification environment measurement" available without real
TPU hardware: per-device HLO FLOPs / bytes from ``compiled.cost_analysis()``
plus per-device collective bytes parsed out of the (SPMD-partitioned) HLO
text.  Three terms:

    compute    = HLO_FLOPs / peak_FLOPs            (197 TFLOP/s bf16 / chip)
    memory     = HLO_bytes / HBM_bw                 (819 GB/s / chip)
    collective = ring-model link bytes / link_bw    (~50 GB/s / link)

Estimated step time = max(terms) (classic roofline).  Collective byte model
per op (g = participating group size, sz = per-device result bytes):
    all-gather         sz * (g-1)/g
    reduce-scatter     sz * (g-1)          (operand is g * result)
    all-reduce         2 * sz * (g-1)/g    (RS + AG phases)
    all-to-all         sz * (g-1)/g
    collective-permute sz
"""
from __future__ import annotations

import dataclasses
import re
from dataclasses import dataclass, field
from typing import Any, Optional

# --- TPU v5e hardware constants (per assignment) ---------------------------
PEAK_FLOPS_BF16 = 197e12        # per chip
HBM_BW = 819e9                  # bytes/s per chip
LINK_BW = 50e9                  # bytes/s per ICI link

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1,
}

_COLLECTIVE_RE = re.compile(
    r"(?P<dtype>\w+)\[(?P<dims>[\d,]*)\][^=]*?=\s*"
    r"(?P<op>all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
)
_TUPLE_COLLECTIVE_RE = re.compile(
    r"=\s*\((?P<types>[^)]*)\)\s*"
    r"(?P<op>all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
)
_GROUPS_V1_RE = re.compile(r"replica_groups=\{(?P<body>[^}]*(?:\{[^}]*\}[^}]*)*)\}")
_GROUPS_V2_RE = re.compile(r"replica_groups=\[(?P<ng>\d+),(?P<gs>\d+)\]")
_TYPE_RE = re.compile(r"(?P<dtype>\w+)\[(?P<dims>[\d,]*)\]")


def _shape_bytes(dtype: str, dims: str) -> int:
    bpe = _DTYPE_BYTES.get(dtype, 4)
    n = 1
    if dims.strip():
        for d in dims.split(","):
            n *= int(d)
    return n * bpe


def _group_size(line: str, default: int) -> int:
    m = _GROUPS_V2_RE.search(line)
    if m:
        return int(m.group("gs"))
    m = _GROUPS_V1_RE.search(line)
    if m:
        body = m.group("body")
        first = body.split("}")[0].lstrip("{")
        ids = [x for x in first.split(",") if x.strip() != ""]
        if ids:
            return len(ids)
    return default


@dataclass
class CollectiveOp:
    op: str
    result_bytes: int
    group_size: int
    line: str

    @property
    def link_bytes(self) -> float:
        g, sz = max(self.group_size, 1), self.result_bytes
        if g <= 1:
            return 0.0
        if self.op == "all-gather":
            return sz * (g - 1) / g
        if self.op == "reduce-scatter":
            return sz * (g - 1)
        if self.op == "all-reduce":
            return 2.0 * sz * (g - 1) / g
        if self.op == "all-to-all":
            return sz * (g - 1) / g
        return float(sz)  # collective-permute


def parse_collectives(hlo_text: str, n_devices: int) -> list[CollectiveOp]:
    ops: list[CollectiveOp] = []
    for line in hlo_text.splitlines():
        if "-start" in line:  # avoid double counting async pairs (-start/-done)
            continue
        m = _COLLECTIVE_RE.search(line)
        if m:
            sz = _shape_bytes(m.group("dtype"), m.group("dims"))
            ops.append(CollectiveOp(m.group("op"), sz,
                                    _group_size(line, n_devices), line.strip()[:160]))
            continue
        m = _TUPLE_COLLECTIVE_RE.search(line)
        if m:
            sz = sum(_shape_bytes(t.group("dtype"), t.group("dims"))
                     for t in _TYPE_RE.finditer(m.group("types")))
            ops.append(CollectiveOp(m.group("op"), sz,
                                    _group_size(line, n_devices), line.strip()[:160]))
    return ops


@dataclass
class Roofline:
    flops: float                 # per-device HLO flops
    hbm_bytes: float             # per-device bytes accessed
    collective_bytes: float      # per-device link bytes (ring model)
    n_devices: int
    collectives: list[CollectiveOp] = field(default_factory=list)
    model_flops: float = 0.0     # 6*N*D useful flops (per device)
    histogram: dict = field(default_factory=dict)      # op@group -> stats
    by_computation: dict = field(default_factory=dict)  # hot-spot breakdown

    @property
    def compute_s(self) -> float:
        return self.flops / PEAK_FLOPS_BF16

    @property
    def memory_s(self) -> float:
        return self.hbm_bytes / HBM_BW

    @property
    def collective_s(self) -> float:
        return self.collective_bytes / LINK_BW

    @property
    def step_s(self) -> float:
        return max(self.compute_s, self.memory_s, self.collective_s)

    @property
    def dominant(self) -> str:
        terms = {"compute": self.compute_s, "memory": self.memory_s,
                 "collective": self.collective_s}
        return max(terms, key=terms.get)

    @property
    def useful_flops_ratio(self) -> float:
        return (self.model_flops / self.flops) if self.flops else 0.0

    @property
    def roofline_fraction(self) -> float:
        """MODEL_FLOPS / (step_s * peak) — the MFU-style score."""
        if self.step_s <= 0:
            return 0.0
        return self.model_flops / (self.step_s * PEAK_FLOPS_BF16)

    def summary(self) -> dict:
        return {
            "flops": self.flops,
            "hbm_bytes": self.hbm_bytes,
            "collective_bytes": self.collective_bytes,
            "compute_s": self.compute_s,
            "memory_s": self.memory_s,
            "collective_s": self.collective_s,
            "step_s": self.step_s,
            "dominant": self.dominant,
            "model_flops": self.model_flops,
            "useful_flops_ratio": self.useful_flops_ratio,
            "roofline_fraction": self.roofline_fraction,
            "n_collectives": len(self.collectives),
        }


def analyze(compiled, hlo_text: Optional[str] = None, n_devices: int = 1,
            model_flops_global: float = 0.0) -> Roofline:
    """Build a Roofline from a compiled executable.

    Primary source: our HLO-text analyzer (``repro.hlo_analysis``) over
    ``compiled.as_text()`` — it applies while-loop trip-count multipliers
    that ``cost_analysis()`` lacks, and extracts per-collective link bytes.
    ``cost_analysis()`` is kept as a cross-check (recorded by callers).
    """
    from repro import hlo_analysis as ha
    text = hlo_text if hlo_text is not None else compiled.as_text()
    hc = ha.analyze_hlo(text, n_devices)
    cols = [CollectiveOp(op, rb, g, "") for (op, rb, g, lb, mult) in hc.collectives
            for _ in range(max(int(mult), 1))] if len(hc.collectives) < 512 else []
    return Roofline(
        flops=hc.flops,
        hbm_bytes=hc.bytes,
        collective_bytes=hc.link_bytes,
        n_devices=n_devices,
        collectives=cols,
        model_flops=model_flops_global / max(n_devices, 1),
        histogram=hc.collective_histogram(),
        by_computation=hc.by_computation,
    )


def model_flops_train(n_params_active: int, n_tokens: int) -> float:
    """6*N*D: fwd 2ND + bwd 4ND."""
    return 6.0 * n_params_active * n_tokens


def model_flops_infer(n_params_active: int, n_tokens: int) -> float:
    return 2.0 * n_params_active * n_tokens
