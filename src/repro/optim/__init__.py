from repro.optim.adamw import AdamWState, adamw_init, adamw_update, OptimizerConfig
from repro.optim.schedule import make_schedule
from repro.optim.compression import (CompressionState, compress_int8,
                                     decompress_int8, ef_compress_update,
                                     ef_init)

__all__ = [
    "AdamWState", "adamw_init", "adamw_update", "OptimizerConfig",
    "make_schedule",
    "CompressionState", "compress_int8", "decompress_int8",
    "ef_compress_update", "ef_init",
]
