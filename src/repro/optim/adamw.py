"""AdamW with decoupled weight decay and global-norm clipping.

Hand-rolled pytree implementation (no optax on the image).  Optimizer state
is fp32 regardless of parameter dtype; state shards exactly like parameters
(same tree structure → same PartitionSpecs).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, NamedTuple, Optional

import jax
import jax.numpy as jnp


@dataclass(frozen=True)
class OptimizerConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0


class AdamWState(NamedTuple):
    step: jax.Array       # () int32
    mu: Any               # fp32 pytree like params
    nu: Any               # fp32 pytree like params


def adamw_init(params: Any) -> AdamWState:
    zeros = jax.tree_util.tree_map(
        lambda p: jnp.zeros(p.shape, jnp.float32), params)
    return AdamWState(jnp.zeros((), jnp.int32), zeros,
                      jax.tree_util.tree_map(jnp.copy, zeros))


def global_norm(tree: Any) -> jax.Array:
    leaves = jax.tree_util.tree_leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(l.astype(jnp.float32))) for l in leaves))


def adamw_update(grads: Any, state: AdamWState, params: Any,
                 cfg: OptimizerConfig, lr: jax.Array | float) -> tuple[Any, AdamWState, dict]:
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.clip_norm / jnp.maximum(gnorm, 1e-9))
    step = state.step + 1
    t = step.astype(jnp.float32)
    bc1 = 1.0 - cfg.b1 ** t
    bc2 = 1.0 - cfg.b2 ** t

    def upd(p, g, m, v):
        g = g.astype(jnp.float32) * scale
        m = cfg.b1 * m + (1 - cfg.b1) * g
        v = cfg.b2 * v + (1 - cfg.b2) * jnp.square(g)
        mhat = m / bc1
        vhat = v / bc2
        delta = mhat / (jnp.sqrt(vhat) + cfg.eps) + cfg.weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * delta).astype(p.dtype), m, v

    flat_p, tdef = jax.tree_util.tree_flatten(params)
    flat_g = jax.tree_util.tree_leaves(grads)
    flat_m = jax.tree_util.tree_leaves(state.mu)
    flat_v = jax.tree_util.tree_leaves(state.nu)
    new = [upd(p, g, m, v) for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = jax.tree_util.tree_unflatten(tdef, [n[0] for n in new])
    new_m = jax.tree_util.tree_unflatten(tdef, [n[1] for n in new])
    new_v = jax.tree_util.tree_unflatten(tdef, [n[2] for n in new])
    metrics = {"grad_norm": gnorm, "lr": jnp.asarray(lr, jnp.float32)}
    return new_p, AdamWState(step, new_m, new_v), metrics
