"""Error-feedback int8 gradient compression for cross-pod all-reduce.

At 512+ chips the inter-pod links carry full-gradient all-reduces every
step; compressing the cross-pod phase 4x (fp32->int8 with per-tensor scale)
cuts that term directly.  Error feedback (Seide et al. 2014; Karimireddy et
al. 2019) accumulates the quantization residual locally so the compressed
SGD trajectory tracks the exact one.

The transfer-hoisting analogy is intentional: this is the paper's
"reduce CPU-GPU transfer" idea applied to the pod-to-pod boundary.

``ef_compress_update`` is pure-pytree (works under jit); the cross-pod
psum itself happens in the train step via a shard_map over the ``pod``
axis when compression is enabled.
"""
from __future__ import annotations

from typing import Any, NamedTuple

import jax
import jax.numpy as jnp


class CompressionState(NamedTuple):
    error: Any   # fp32 residual pytree (error feedback memory)


def ef_init(params: Any) -> CompressionState:
    return CompressionState(jax.tree_util.tree_map(
        lambda p: jnp.zeros(p.shape, jnp.float32), params))


def compress_int8(x: jax.Array) -> tuple[jax.Array, jax.Array]:
    """fp32 -> (int8 values, fp32 scale).  Symmetric per-tensor quantization."""
    amax = jnp.max(jnp.abs(x))
    scale = jnp.maximum(amax, 1e-20) / 127.0
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return q, scale


def decompress_int8(q: jax.Array, scale: jax.Array) -> jax.Array:
    return q.astype(jnp.float32) * scale


def ef_compress_update(grads: Any, state: CompressionState
                       ) -> tuple[Any, Any, CompressionState]:
    """Returns (quantized pytree, scales pytree, new error state).

    Caller all-reduces the quantized values (as int32/float32 sums of int8
    payloads), then divides by the replica count and multiplies by scale.
    """
    def one(g, e):
        corrected = g.astype(jnp.float32) + e
        q, scale = compress_int8(corrected)
        new_e = corrected - decompress_int8(q, scale)
        return q, scale, new_e

    flat_g, tdef = jax.tree_util.tree_flatten(grads)
    flat_e = jax.tree_util.tree_leaves(state.error)
    outs = [one(g, e) for g, e in zip(flat_g, flat_e)]
    qs = jax.tree_util.tree_unflatten(tdef, [o[0] for o in outs])
    scales = jax.tree_util.tree_unflatten(tdef, [o[1] for o in outs])
    errs = jax.tree_util.tree_unflatten(tdef, [o[2] for o in outs])
    return qs, scales, CompressionState(errs)
