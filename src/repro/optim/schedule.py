"""LR schedules: linear warmup + cosine decay (the production default)."""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np


def make_schedule(kind: str = "cosine", *, peak_lr: float = 3e-4,
                  warmup_steps: int = 100, total_steps: int = 10_000,
                  final_frac: float = 0.1):
    def sched(step):
        s = jnp.asarray(step, jnp.float32)
        warm = peak_lr * jnp.minimum(1.0, s / max(warmup_steps, 1))
        if kind == "constant":
            return warm
        prog = jnp.clip((s - warmup_steps) / max(total_steps - warmup_steps, 1), 0.0, 1.0)
        if kind == "linear":
            decay = peak_lr * (1.0 - (1.0 - final_frac) * prog)
        else:  # cosine
            decay = peak_lr * (final_frac + (1 - final_frac) * 0.5 *
                               (1.0 + jnp.cos(np.pi * prog)))
        return jnp.where(s < warmup_steps, warm, decay)
    return sched
