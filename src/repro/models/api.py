"""Model facade: one uniform interface over every assigned architecture.

``build_model(cfg)`` returns a :class:`Model` with pure functions:
    init(rng) / param_shapes() / loss / prefill / decode / input_specs(shape)

``input_specs`` returns ShapeDtypeStruct stand-ins for every model input of a
benchmark cell — weak-type-correct, shardable, no device allocation — which
is what the multi-pod dry-run lowers against.
"""
from __future__ import annotations

import functools
from dataclasses import dataclass
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig, ShapeSpec
from repro.models import transformer as T
from repro.models import whisper as WH
from repro.models.plan import ExecPlan

Sds = jax.ShapeDtypeStruct


@dataclass(frozen=True)
class Model:
    cfg: ArchConfig

    # ------------------------------------------------------------------ init
    def init(self, rng: jax.Array, dtype=jnp.float32) -> dict:
        if self.cfg.family == "encdec":
            return WH.init_params(self.cfg, rng, dtype)
        return T.init_params(self.cfg, rng, dtype)

    def param_shapes(self, dtype=jnp.float32) -> Any:
        return jax.eval_shape(
            lambda: self.init(jax.random.key(0), dtype=dtype))

    # ------------------------------------------------------------------ steps
    def loss(self, params: dict, batch: dict, plan: ExecPlan):
        if self.cfg.family == "encdec":
            return WH.lm_loss(params, batch, self.cfg, plan)
        return T.lm_loss(params, batch, self.cfg, plan)

    def prefill(self, params: dict, inputs: dict, plan: ExecPlan,
                cache_capacity: int = 0):
        if self.cfg.family == "encdec":
            return WH.prefill(params, self.cfg, plan, inputs["tokens"],
                              inputs["frames"], cache_capacity)
        return T.prefill(params, self.cfg, plan, inputs["tokens"],
                         inputs.get("patch_feats"), cache_capacity)

    def decode(self, params: dict, token: jax.Array, state: dict, plan: ExecPlan):
        if self.cfg.family == "encdec":
            return WH.decode_step(params, self.cfg, plan, token, state)
        return T.decode_step(params, self.cfg, plan, token, state)

    # ------------------------------------------------------------- input specs
    def _token_len(self, shape: ShapeSpec) -> int:
        """Text-token length for a cell (VLM reserves room for patches)."""
        s = shape.seq_len
        if self.cfg.vision_patches:
            s = s - self.cfg.vision_patches
            assert s > 0, f"seq {shape.seq_len} too short for vision prefix"
        return s

    def input_specs(self, shape: ShapeSpec) -> dict:
        """ShapeDtypeStruct stand-ins for one benchmark cell."""
        cfg = self.cfg
        b = shape.global_batch
        s = self._token_len(shape)
        i32 = jnp.int32
        if shape.kind == "train":
            specs = {"tokens": Sds((b, s), i32), "labels": Sds((b, s), i32)}
            if cfg.family == "encdec":
                specs["frames"] = Sds((b, cfg.encoder_seq, cfg.d_model), jnp.bfloat16)
            if cfg.vision_patches:
                specs["patch_feats"] = Sds((b, cfg.vision_patches, cfg.vision_dim),
                                           jnp.bfloat16)
            return specs
        if shape.kind == "prefill":
            specs = {"tokens": Sds((b, s), i32)}
            if cfg.family == "encdec":
                specs["frames"] = Sds((b, cfg.encoder_seq, cfg.d_model), jnp.bfloat16)
            if cfg.vision_patches:
                specs["patch_feats"] = Sds((b, cfg.vision_patches, cfg.vision_dim),
                                           jnp.bfloat16)
            return specs
        # decode: one token + a state whose cache capacity is shape.seq_len
        return {
            "token": Sds((b, 1), i32),
            "state": self.state_specs(shape),
        }

    def state_specs(self, shape: ShapeSpec) -> Any:
        """Decode-state ShapeDtypeStructs via eval_shape over prefill."""
        cfg = self.cfg
        b = shape.global_batch
        # decode = "one new token against a cache of seq_len": prefill one
        # short so the cache has a free slot at capacity seq_len.
        s = self._token_len(shape) - 1
        params = self.param_shapes()
        plan = ExecPlan()  # state structure is plan-independent
        prefill_inputs = {"tokens": Sds((b, s), jnp.int32)}
        if cfg.family == "encdec":
            prefill_inputs["frames"] = Sds((b, cfg.encoder_seq, cfg.d_model), jnp.bfloat16)
        if cfg.vision_patches:
            prefill_inputs["patch_feats"] = Sds((b, cfg.vision_patches, cfg.vision_dim),
                                                jnp.bfloat16)

        def run(p, inp):
            _, state = self.prefill(p, inp, plan, cache_capacity=shape.seq_len)
            return state

        return jax.eval_shape(run, params, prefill_inputs)

    # ------------------------------------------------------------ demo batch
    def demo_batch(self, rng: jax.Array, batch: int, seq: int) -> dict:
        cfg = self.cfg
        k1, k2, k3 = jax.random.split(rng, 3)
        s = seq - (cfg.vision_patches or 0)
        out = {
            "tokens": jax.random.randint(k1, (batch, s), 0, cfg.vocab, jnp.int32),
            "labels": jax.random.randint(k2, (batch, s), 0, cfg.vocab, jnp.int32),
        }
        if cfg.family == "encdec":
            out["frames"] = jax.random.normal(
                k3, (batch, cfg.encoder_seq, cfg.d_model), jnp.bfloat16)
        if cfg.vision_patches:
            out["patch_feats"] = jax.random.normal(
                k3, (batch, cfg.vision_patches, cfg.vision_dim), jnp.bfloat16)
        return out


def build_model(cfg: ArchConfig) -> Model:
    return Model(cfg)
