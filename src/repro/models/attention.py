"""Attention: GQA/MQA, full-causal / local-window / cross, KV-cache decode.

Three interchangeable region implementations (selected by the ExecPlan — the
paper's per-loop offload gene):

* ``naive``   — materialize (Sq, Sk) scores.  Reference path.
* ``chunked`` — flash-style online softmax over KV chunks; peak memory bounded
                by the KV chunk size.  jnp twin of ``kernels/flash_attention``.
* local attention always uses the banded formulation (sub-quadratic).

All paths upcast scores to f32 for the softmax and compute matmuls in the
plan's compute dtype.
"""
from __future__ import annotations

import functools
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig
from repro.models import layers as L
from repro.models.plan import ExecPlan
from repro.runtime.pspec import constrain

Array = jax.Array
NEG_INF = -1e30


class KVCache(NamedTuple):
    k: Array  # (B, S_cache, Hkv, D)
    v: Array  # (B, S_cache, Hkv, D)


# ---------------------------------------------------------------------------
# params
# ---------------------------------------------------------------------------


def attn_init(key, cfg: ArchConfig, cross: bool = False, dtype=jnp.float32) -> dict:
    d, hd = cfg.d_model, cfg.resolved_head_dim
    nq, nkv = cfg.n_heads, cfg.n_kv_heads
    ks = jax.random.split(key, 4)
    p = {
        "wq": L.dense_init(ks[0], (d, nq * hd), dtype=dtype),
        "wk": L.dense_init(ks[1], (d, nkv * hd), dtype=dtype),
        "wv": L.dense_init(ks[2], (d, nkv * hd), dtype=dtype),
        "wo": L.dense_init(ks[3], (nq * hd, d), dtype=dtype),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((nq * hd,), dtype)
        p["bk"] = jnp.zeros((nkv * hd,), dtype)
        p["bv"] = jnp.zeros((nkv * hd,), dtype)
    if cfg.qk_norm:
        p["q_norm"] = jnp.zeros((hd,), dtype)
        p["k_norm"] = jnp.zeros((hd,), dtype)
    return p


def project_q(x: Array, p: dict, cfg: ArchConfig, plan: ExecPlan, positions: Array) -> Array:
    dt = L.cdtype(plan)
    b, s, _ = x.shape
    hd = cfg.resolved_head_dim
    q = x @ p["wq"].astype(dt)
    if cfg.qkv_bias:
        q = q + p["bq"].astype(dt)
    q = q.reshape(b, s, cfg.n_heads, hd)
    if cfg.qk_norm:
        q = L.rmsnorm(q, p["q_norm"], cfg.norm_eps, plan)
    q = L.apply_rope(q, positions, cfg.rope_theta)
    return q


def project_kv(x: Array, p: dict, cfg: ArchConfig, plan: ExecPlan,
               positions: Array) -> tuple[Array, Array]:
    dt = L.cdtype(plan)
    b, s, _ = x.shape
    hd = cfg.resolved_head_dim
    k = x @ p["wk"].astype(dt)
    v = x @ p["wv"].astype(dt)
    if cfg.qkv_bias:
        k = k + p["bk"].astype(dt)
        v = v + p["bv"].astype(dt)
    k = k.reshape(b, s, cfg.n_kv_heads, hd)
    v = v.reshape(b, s, cfg.n_kv_heads, hd)
    if cfg.qk_norm:
        k = L.rmsnorm(k, p["k_norm"], cfg.norm_eps, plan)
    k = L.apply_rope(k, positions, cfg.rope_theta)
    return k, v


def project_qkv(x: Array, p: dict, cfg: ArchConfig, plan: ExecPlan,
                positions: Array) -> tuple[Array, Array, Array]:
    """Either three matmuls (ref) or one fused qkv matmul (offloaded)."""
    dt = L.cdtype(plan)
    b, s, _ = x.shape
    hd = cfg.resolved_head_dim
    nq, nkv = cfg.n_heads, cfg.n_kv_heads
    if plan.qkv_fused:
        wqkv = jnp.concatenate([p["wq"], p["wk"], p["wv"]], axis=1).astype(dt)
        qkv = x @ wqkv
        if cfg.qkv_bias:
            qkv = qkv + jnp.concatenate([p["bq"], p["bk"], p["bv"]]).astype(dt)
        q, k, v = jnp.split(qkv, [nq * hd, (nq + nkv) * hd], axis=-1)
        q = q.reshape(b, s, nq, hd)
        k = k.reshape(b, s, nkv, hd)
        v = v.reshape(b, s, nkv, hd)
        if cfg.qk_norm:
            q = L.rmsnorm(q, p["q_norm"], cfg.norm_eps, plan)
            k = L.rmsnorm(k, p["k_norm"], cfg.norm_eps, plan)
        q = L.apply_rope(q, positions, cfg.rope_theta)
        k = L.apply_rope(k, positions, cfg.rope_theta)
        return q, k, v
    q = project_q(x, p, cfg, plan, positions)
    k, v = project_kv(x, p, cfg, plan, positions)
    return q, k, v


def _group(q: Array, n_kv: int) -> Array:
    """(B,S,Hq,D) -> (B,S,Hkv,G,D)."""
    b, s, hq, d = q.shape
    return q.reshape(b, s, n_kv, hq // n_kv, d)


def _repeat_kv(k: Array, group: int) -> Array:
    """(B,S,Hkv,D) -> (B,S,Hq,D) by repeating kv heads (GQA)."""
    return jnp.repeat(k, group, axis=2) if group > 1 else k


def cache_axes(n_kv_heads: int) -> tuple:
    """Logical axes for a (B, Sc, Hkv, D) KV-cache entry: heads over "model"
    when divisible, else the cache sequence dim (matches
    runtime.sharding._axes_for_state so prefill output needs no reshard)."""
    from repro.runtime.pspec import current_rules
    rules = current_rules()
    if rules is None:
        return ("batch", None, "kv_heads", None)
    msize = rules.mesh.shape.get("model", 1)
    if n_kv_heads % msize == 0:
        return ("batch", None, "kv_heads", None)
    return ("batch", "kv_seq", None, None)


def _score_axes(n_heads: int) -> tuple:
    """Sharding for (B,H,Sq,...) score-like tensors: heads over "model" when
    divisible, else sequence-parallel on Sq.  Falls back to no-op without an
    active mesh."""
    from repro.runtime.pspec import current_rules
    rules = current_rules()
    if rules is None:
        return ("batch", "heads", None)
    msize = rules.mesh.shape.get("model", 1)
    if n_heads % msize == 0:
        return ("batch", "heads", None)
    return ("batch", None, "seq_sp")


# ---------------------------------------------------------------------------
# naive full attention (reference)
# ---------------------------------------------------------------------------


def attend_naive(q: Array, k: Array, v: Array, pos_q: Array, pos_k: Array,
                 causal: bool, window: int, plan: ExecPlan) -> Array:
    b, sq, hq, hd = q.shape
    nkv = k.shape[2]
    ax = _score_axes(hq)
    # (B,H,S,D) layout; kv heads repeated for GQA.  Scores shard over heads
    # (TP-natural) or the q-seq dim — never replicated (on real TPU the
    # Pallas flash kernel removes the score tensor entirely).
    qh = constrain(q.transpose(0, 2, 1, 3), ax[0], ax[1], ax[2], None)
    kh = _repeat_kv(k, hq // nkv).transpose(0, 2, 1, 3)
    vh = _repeat_kv(v, hq // nkv).transpose(0, 2, 1, 3)
    scale = 1.0 / np.sqrt(hd)
    scores = jnp.einsum("bhqd,bhkd->bhqk", qh, kh,
                        preferred_element_type=jnp.float32) * scale
    scores = constrain(scores, ax[0], ax[1], ax[2], None)
    mask = jnp.ones((sq, k.shape[1]), bool)
    if causal:
        mask &= pos_k[None, :] <= pos_q[:, None]
    if window > 0:
        mask &= pos_k[None, :] > pos_q[:, None] - window
    scores = jnp.where(mask[None, None], scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1).astype(L.cdtype(plan))
    out = jnp.einsum("bhqk,bhkd->bhqd", probs, vh)
    return out.transpose(0, 2, 1, 3)


# ---------------------------------------------------------------------------
# chunked (flash-style) attention — the offloaded path
#
# ``_flash`` is a custom_vjp: plain autodiff through the online-softmax scan
# stacks the per-chunk (Sq, ck) score tensors as saved residuals (measured:
# 2.7 GB/layer + replication all-gathers at train_4k), defeating the whole
# point.  The custom backward recomputes probabilities chunk-by-chunk from
# the saved (q, k, v, out, logsumexp) — exactly the Pallas kernel's backward.
# ---------------------------------------------------------------------------


def _flash_mask(pos_q, pos_k, causal: bool, window: int, sk_valid: int):
    mask = pos_k[None, :] < sk_valid          # padded keys masked out
    if causal:
        mask &= pos_k[None, :] <= pos_q[:, None]
    if window > 0:
        mask &= pos_k[None, :] > pos_q[:, None] - window
    return mask


def _chunk_kv(x: Array, ck: int) -> Array:
    bh, sk, d = x.shape
    return x.reshape(bh, sk // ck, ck, d).transpose(1, 0, 2, 3)   # (n,BH,ck,D)


@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5, 6, 7))
def _flash(q: Array, k: Array, v: Array, causal: bool, window: int,
           ck: int, out_dtype, sk_valid: int) -> Array:
    """Flattened-head flash attention.  q: (BH, Sq, D); k/v: (BH, Sk, D)
    (equal heads — GQA repeat outside).  Sk must be a multiple of ck (padded
    by the caller; sk_valid = true length).  Runs LOCALLY under shard_map —
    no sharding constraints inside."""
    out, _ = _flash_fwd(q, k, v, causal, window, ck, out_dtype, sk_valid)
    return out


def _flash_fwd(q, k, v, causal, window, ck, out_dtype, sk_valid):
    bh, sq, hd = q.shape
    sk = k.shape[1]
    pos_q = jnp.arange(sq, dtype=jnp.int32)
    pos_k = jnp.arange(sk, dtype=jnp.int32)
    kc, vc = _chunk_kv(k, ck), _chunk_kv(v, ck)
    pkc = pos_k.reshape(-1, ck)
    scale = 1.0 / np.sqrt(hd)

    def body(carry, chunk):
        m, l, acc = carry
        k_j, v_j, pk_j = chunk                                    # (BH,ck,D)
        s = jnp.einsum("bqd,bkd->bqk", q, k_j,
                       preferred_element_type=jnp.float32) * scale
        s = jnp.where(_flash_mask(pos_q, pk_j, causal, window, sk_valid)[None],
                      s, NEG_INF)
        m_new = jnp.maximum(m, jnp.max(s, axis=-1))
        p = jnp.exp(s - m_new[..., None])
        corr = jnp.exp(m - m_new)
        l_new = l * corr + jnp.sum(p, axis=-1)
        pv = jnp.einsum("bqk,bkd->bqd", p.astype(k_j.dtype), v_j)
        acc_new = acc * corr[..., None] + pv.astype(jnp.float32)
        return (m_new, l_new, acc_new), None

    init = (jnp.full((bh, sq), NEG_INF, jnp.float32),
            jnp.zeros((bh, sq), jnp.float32),
            jnp.zeros((bh, sq, hd), jnp.float32))
    (m, l, acc), _ = jax.lax.scan(body, init, (kc, vc, pkc))
    out = (acc / jnp.maximum(l, 1e-37)[..., None]).astype(out_dtype)
    lse = m + jnp.log(jnp.maximum(l, 1e-37))
    return out, (q, k, v, out, lse)


def _flash_bwd(causal, window, ck, out_dtype, sk_valid, res, dout):
    q, k, v, out, lse = res
    bh, sq, hd = q.shape
    sk = k.shape[1]
    pos_q = jnp.arange(sq, dtype=jnp.int32)
    pos_k = jnp.arange(sk, dtype=jnp.int32)
    kc, vc = _chunk_kv(k, ck), _chunk_kv(v, ck)
    pkc = pos_k.reshape(-1, ck)
    scale = 1.0 / np.sqrt(hd)
    do = dout.astype(jnp.float32)
    delta = jnp.sum(do * out.astype(jnp.float32), axis=-1)        # (BH,Sq)

    def body(dq, chunk):
        k_j, v_j, pk_j = chunk
        s = jnp.einsum("bqd,bkd->bqk", q, k_j,
                       preferred_element_type=jnp.float32) * scale
        s = jnp.where(_flash_mask(pos_q, pk_j, causal, window, sk_valid)[None],
                      s, NEG_INF)
        p = jnp.exp(s - lse[..., None])                           # (BH,Sq,ck)
        dv_j = jnp.einsum("bqk,bqd->bkd", p, do)
        dp = jnp.einsum("bqd,bkd->bqk", do, v_j.astype(jnp.float32))
        ds = p * (dp - delta[..., None]) * scale
        dq = dq + jnp.einsum("bqk,bkd->bqd", ds, k_j.astype(jnp.float32))
        dk_j = jnp.einsum("bqk,bqd->bkd", ds, q.astype(jnp.float32))
        return dq, (dk_j, dv_j)

    dq0 = jnp.zeros((bh, sq, hd), jnp.float32)
    dq, (dks, dvs) = jax.lax.scan(body, dq0, (kc, vc, pkc))
    dk = dks.transpose(1, 0, 2, 3).reshape(bh, sk, hd)
    dv = dvs.transpose(1, 0, 2, 3).reshape(bh, sk, hd)
    return (dq.astype(q.dtype), dk.astype(k.dtype), dv.astype(v.dtype))


_flash.defvjp(_flash_fwd, _flash_bwd)


def _bh_axes(bh: int) -> tuple:
    """Longest mesh-axis tuple dividing the flattened (B*H) dim."""
    from repro.runtime.pspec import current_rules
    rules = current_rules()
    if rules is None:
        return ()
    mesh = rules.mesh
    for cand in (("pod", "data", "model"), ("data", "model"), ("pod", "data"),
                 ("data",), ("model",)):
        axes = tuple(a for a in cand if a in mesh.shape)
        if not axes or axes != tuple(cand[-len(axes):]) and axes != tuple(cand):
            pass
        size = 1
        for a in axes:
            size *= mesh.shape[a]
        if axes and bh % size == 0:
            return axes
    return ()


def attend_chunked(q: Array, k: Array, v: Array, pos_q: Array, pos_k: Array,
                   causal: bool, window: int, plan: ExecPlan) -> Array:
    """Flash attention over KV chunks with a custom backward (recompute, no
    stacked score residuals).  The (B, H) dims flatten into one leading dim
    sharded across the whole mesh with shard_map: compute is fully local —
    zero collectives inside attention.  jnp twin of kernels/flash_attention.
    Positions must be aranges (true for every full-sequence caller)."""
    from jax.sharding import PartitionSpec as P
    from repro.runtime.pspec import axis_rules, current_rules

    b, sq, hq, hd = q.shape
    sk = k.shape[1]
    nkv = k.shape[2]
    group = hq // nkv
    ck = min(plan.attn_kv_chunk, sk)
    pad = (-sk) % ck
    kh = _repeat_kv(k, group)                     # (B,Sk,H,D); grad sums groups
    vh = _repeat_kv(v, group)
    if pad:
        kh = jnp.pad(kh, ((0, 0), (0, pad), (0, 0), (0, 0)))
        vh = jnp.pad(vh, ((0, 0), (0, pad), (0, 0), (0, 0)))
    # anchor the (B,S,H,D) <-> (BH,S,D) transitions on the TP-natural head
    # sharding so the boundary reshards are local relayouts, not gathers
    hax = _score_axes(hq)[1]  # "heads" when divisible, else None
    q = constrain(q, "batch", None, hax, None)
    kh = constrain(kh, "batch", None, hax, None)
    vh = constrain(vh, "batch", None, hax, None)
    qf = q.transpose(0, 2, 1, 3).reshape(b * hq, sq, hd)
    kf = kh.transpose(0, 2, 1, 3).reshape(b * hq, -1, hd)
    vf = vh.transpose(0, 2, 1, 3).reshape(b * hq, -1, hd)

    rules = current_rules()
    bh = b * hq
    axes = _bh_axes(bh)
    # non-divisible (B*H) (e.g. 20 heads on a 16-way axis) would fall back to
    # partial sharding and replicate score rows 16x — pad BH to the full mesh
    # instead (zero rows cost nothing; outputs sliced away)
    pad_bh = 0
    if rules is not None:
        full = tuple(a for a in ("pod", "data", "model") if a in rules.mesh.shape)
        fsize = 1
        for a in full:
            fsize *= rules.mesh.shape[a]
        cur = 1
        for a in axes:
            cur *= rules.mesh.shape[a]
        if fsize > cur:
            pad_bh = (-bh) % fsize
            axes = full
    if pad_bh:
        qf = jnp.pad(qf, ((0, pad_bh), (0, 0), (0, 0)))
        kf = jnp.pad(kf, ((0, pad_bh), (0, 0), (0, 0)))
        vf = jnp.pad(vf, ((0, pad_bh), (0, 0), (0, 0)))
    if rules is None or not axes:
        out = _flash(qf, kf, vf, causal, window, ck, L.cdtype(plan), sk)
    else:
        spec = P(axes if len(axes) > 1 else axes[0], None, None)

        def inner(qi, ki, vi):
            with axis_rules(None):
                return _flash(qi, ki, vi, causal, window, ck, L.cdtype(plan), sk)

        from repro.runtime.pspec import shard_map_compat
        out = shard_map_compat(inner, mesh=rules.mesh,
                               in_specs=(spec, spec, spec),
                               out_specs=spec)(qf, kf, vf)
    if pad_bh:
        out = out[:bh]
    out = out.reshape(b, hq, sq, hd).transpose(0, 2, 1, 3)
    return constrain(out, "batch", None, hax, None)


# ---------------------------------------------------------------------------
# banded local attention (sub-quadratic; always used for attn_kind=local when
# the sequence is longer than the window)
# ---------------------------------------------------------------------------


def attend_local_banded(q: Array, k: Array, v: Array, pos_q: Array, pos_k: Array,
                        window: int, plan: ExecPlan) -> Array:
    """Each q chunk (size w) attends its own + previous kv chunk only.

    Exact for causal local attention with window <= chunk size: query at
    position p sees (p - w, p].  FLOPs: 2*w per query — sub-quadratic.
    """
    b, sq, hq, hd = q.shape
    nkv = k.shape[2]
    w = window
    if sq % w != 0 or k.shape[1] != sq:
        # fallback (ragged tails handled by the generic chunked path)
        return attend_chunked(q, k, v, pos_q, pos_k, True, window, plan)
    n = sq // w
    qc = _group(q, nkv).reshape(b, n, w, nkv, hq // nkv, hd)
    qc = constrain(qc, "batch", "seq_sp", None, None, None, None)  # SP chunks
    kc = k.reshape(b, n, w, nkv, hd)
    vc = v.reshape(b, n, w, nkv, hd)
    k_prev = jnp.concatenate([jnp.zeros_like(kc[:, :1]), kc[:, :-1]], axis=1)
    v_prev = jnp.concatenate([jnp.zeros_like(vc[:, :1]), vc[:, :-1]], axis=1)
    kk = jnp.concatenate([k_prev, kc], axis=2)  # (B,n,2w,Hkv,D)
    vv = jnp.concatenate([v_prev, vc], axis=2)
    pq = pos_q.reshape(n, w)
    pk = pos_k.reshape(n, w)
    pk_prev = jnp.concatenate(
        [jnp.full_like(pk[:1], np.iinfo(np.int32).max), pk[:-1]], axis=0)
    pkk = jnp.concatenate([pk_prev, pk], axis=1)  # (n, 2w)

    scale = 1.0 / np.sqrt(hd)
    s = jnp.einsum("bnqhgd,bnkhd->bnhgqk", qc, kk,
                   preferred_element_type=jnp.float32) * scale
    mask = (pkk[:, None, :] <= pq[:, :, None]) & (pkk[:, None, :] > pq[:, :, None] - w)
    s = jnp.where(mask[None, :, None, None], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1).astype(L.cdtype(plan))
    out = jnp.einsum("bnhgqk,bnkhd->bnqhgd", p, vv)
    return out.reshape(b, sq, hq, hd)


# ---------------------------------------------------------------------------
# decode (single new token against a cache)
# ---------------------------------------------------------------------------


def attend_decode(q1: Array, cache: KVCache, cache_len: Array,
                  window: int, plan: ExecPlan, ring: bool) -> Array:
    """q1: (B,1,Hq,D); cache.k/v: (B,Sc,Hkv,D).  Returns (B,1,Hq,D).

    ``ring`` means the cache is a ring buffer of size `window` (local attn);
    otherwise it is a linear buffer with `cache_len` valid entries.
    """
    b, _, hq, hd = q1.shape
    sc, nkv = cache.k.shape[1], cache.k.shape[2]
    qg = _group(q1, nkv)[:, 0]  # (B,Hkv,G,D)
    scale = 1.0 / np.sqrt(hd)
    s = jnp.einsum("bhgd,bkhd->bhgk", qg, cache.k,
                   preferred_element_type=jnp.float32) * scale
    idx = jnp.arange(sc)
    if ring:
        # valid entries: the min(cache_len, window) most recent slots
        age = (cache_len - 1 - idx) % sc  # 0 = newest
        valid = age < jnp.minimum(cache_len, sc)
    else:
        valid = idx < cache_len
        if window > 0:
            valid &= idx > cache_len - 1 - window
    s = jnp.where(valid[None, None, None], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1).astype(L.cdtype(plan))
    out = jnp.einsum("bhgk,bkhd->bhgd", p, cache.v)
    return out.reshape(b, 1, hq, hd)


def cache_update(cache: KVCache, k1: Array, v1: Array, cache_len: Array,
                 ring: bool) -> KVCache:
    """Insert one token's k/v at the right slot (ring or linear)."""
    sc = cache.k.shape[1]
    slot = (cache_len % sc) if ring else cache_len
    k = jax.lax.dynamic_update_slice_in_dim(cache.k, k1, slot, axis=1)
    v = jax.lax.dynamic_update_slice_in_dim(cache.v, v1, slot, axis=1)
    return KVCache(k, v)


# ---------------------------------------------------------------------------
# dispatcher
# ---------------------------------------------------------------------------


def attend(q: Array, k: Array, v: Array, pos_q: Array, pos_k: Array, *,
           causal: bool, attn_kind: str, window: int, plan: ExecPlan) -> Array:
    if attn_kind == "local" and causal and q.shape[1] > window:
        return attend_local_banded(q, k, v, pos_q, pos_k, window, plan)
    win = window if attn_kind == "local" else 0
    if plan.attn_impl == "chunked":
        return attend_chunked(q, k, v, pos_q, pos_k, causal, win, plan)
    return attend_naive(q, k, v, pos_q, pos_k, causal, win, plan)
