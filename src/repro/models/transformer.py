"""Unified decoder-only LM covering dense / MoE / hybrid(RG-LRU) / SSM(RWKV6)
/ VLM families, with scan-over-layers stacked parameters.

Three entry points per model (built by ``models/api.py``):
  * ``loss``    — training forward + masked cross-entropy (+ MoE aux)
  * ``prefill`` — full-sequence forward returning logits + decode state
  * ``decode``  — one-token step against the decode state

The decode state is a plain nested dict of arrays (stacked per-layer leaves)
so it shards/specs like any pytree.  Implementation choices come from the
ExecPlan (the paper's offload genes).
"""
from __future__ import annotations

import functools
from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig
from repro.models import attention as A
from repro.models import layers as L
from repro.models import moe as M
from repro.models import rglru as R
from repro.models import rwkv as W
from repro.models.plan import ExecPlan
from repro.runtime.pspec import constrain

Array = jax.Array


# ---------------------------------------------------------------------------
# remat policy
# ---------------------------------------------------------------------------


def _maybe_remat(fn, plan: ExecPlan):
    if plan.remat == "none":
        return fn
    if plan.remat == "dots":
        return jax.checkpoint(fn, policy=jax.checkpoint_policies.dots_with_no_batch_dims_saveable)
    return jax.checkpoint(fn)  # "full": save nothing


# ---------------------------------------------------------------------------
# parameter init
# ---------------------------------------------------------------------------


def _stack_init(key, n: int, init_fn) -> Any:
    """Initialize n copies of a param dict and stack leaves on axis 0."""
    keys = jax.random.split(key, n)
    trees = [init_fn(k) for k in keys]
    return jax.tree_util.tree_map(lambda *xs: jnp.stack(xs), *trees)


def _dense_block_init(key, cfg: ArchConfig, dtype) -> dict:
    k1, k2 = jax.random.split(key)
    blk = {
        "ln1": jnp.zeros((cfg.d_model,), dtype),
        "ln2": jnp.zeros((cfg.d_model,), dtype),
        "attn": A.attn_init(k1, cfg, dtype=dtype),
    }
    if cfg.moe is not None:
        blk["moe"] = M.moe_init(k2, cfg, dtype=dtype)
    else:
        blk["mlp"] = L.mlp_init(k2, cfg.d_model, cfg.d_ff, dtype=dtype)
    return blk


def _hybrid_sub_init(key, cfg: ArchConfig, kind: str, dtype) -> dict:
    k1, k2 = jax.random.split(key)
    sub = {
        "ln1": jnp.zeros((cfg.d_model,), dtype),
        "ln2": jnp.zeros((cfg.d_model,), dtype),
        "mlp": L.mlp_init(k2, cfg.d_model, cfg.d_ff, dtype=dtype),
    }
    if kind == "rglru":
        sub["rglru"] = R.rglru_init(k1, cfg, dtype=dtype)
    else:
        sub["attn"] = A.attn_init(k1, cfg, dtype=dtype)
    return sub


def _hybrid_macro_init(key, cfg: ArchConfig, dtype) -> dict:
    ks = jax.random.split(key, len(cfg.block_pattern))
    return {f"sub{i}": _hybrid_sub_init(ks[i], cfg, kind, dtype)
            for i, kind in enumerate(cfg.block_pattern)}


def _rwkv_block_init(key, cfg: ArchConfig, dtype) -> dict:
    return {
        "ln1_s": jnp.ones((cfg.d_model,), dtype),
        "ln1_b": jnp.zeros((cfg.d_model,), dtype),
        "ln2_s": jnp.ones((cfg.d_model,), dtype),
        "ln2_b": jnp.zeros((cfg.d_model,), dtype),
        "tm_cm": W.rwkv_init(key, cfg, dtype=dtype),
    }


def init_params(cfg: ArchConfig, rng: jax.Array, dtype=jnp.float32) -> dict:
    k_embed, k_blocks, k_head, k_extra = jax.random.split(rng, 4)
    params: dict = {"embed": L.embed_init(k_embed, (cfg.vocab, cfg.d_model), dtype)}
    if not cfg.tie_embeddings:
        params["lm_head"] = L.embed_init(k_head, (cfg.vocab, cfg.d_model), dtype)
    params["final_norm"] = jnp.zeros((cfg.d_model,), dtype)

    if cfg.family == "ssm":
        params["embed_norm_s"] = jnp.ones((cfg.d_model,), dtype)
        params["embed_norm_b"] = jnp.zeros((cfg.d_model,), dtype)
        params["blocks"] = _stack_init(
            k_blocks, cfg.n_layers, lambda k: _rwkv_block_init(k, cfg, dtype))
    elif cfg.family == "hybrid":
        period = len(cfg.block_pattern)
        n_macro, rem = divmod(cfg.n_layers, period)
        kp, km = jax.random.split(k_blocks)
        if rem:
            pre_ks = jax.random.split(kp, rem)
            params["pre_blocks"] = [
                _hybrid_sub_init(pre_ks[i], cfg, "rglru", dtype) for i in range(rem)]
        params["blocks"] = _stack_init(
            km, n_macro, lambda k: _hybrid_macro_init(k, cfg, dtype))
    else:  # dense / moe / vlm trunk
        params["blocks"] = _stack_init(
            k_blocks, cfg.n_layers, lambda k: _dense_block_init(k, cfg, dtype))

    if cfg.vision_patches:
        kv1, kv2 = jax.random.split(k_extra)
        params["projector"] = {
            "vis_w1": L.dense_init(kv1, (cfg.vision_dim, cfg.d_model), dtype=dtype),
            "vis_b1": jnp.zeros((cfg.d_model,), dtype),
            "vis_w2": L.dense_init(kv2, (cfg.d_model, cfg.d_model), dtype=dtype),
            "vis_b2": jnp.zeros((cfg.d_model,), dtype),
        }
    return params


# ---------------------------------------------------------------------------
# block forward — full-sequence mode (train / prefill)
# ---------------------------------------------------------------------------


def _attn_sublayer_full(x, p_attn, ln, cfg: ArchConfig, plan: ExecPlan,
                        positions, want_cache: bool, cache_capacity: int):
    b, s, _ = x.shape
    h = L.rmsnorm(x, ln, cfg.norm_eps, plan)
    q, k, v = A.project_qkv(h, p_attn, cfg, plan, positions)
    o = A.attend(q, k, v, positions, positions, causal=True,
                 attn_kind=cfg.attn_kind, window=cfg.local_window, plan=plan)
    o = o.reshape(b, s, -1) @ p_attn["wo"].astype(L.cdtype(plan))
    o = constrain(o, "batch", "seq", None)
    cache = None
    if want_cache:
        if cfg.attn_kind == "local":
            w = cfg.local_window
            kc = k[:, -w:]
            vc = v[:, -w:]
            # ring layout: slot = position % window
            roll = (s % w) - w
            kc = jnp.roll(kc, roll, axis=1) if s >= w else jnp.pad(k, ((0, 0), (0, w - s), (0, 0), (0, 0)))
            vc = jnp.roll(vc, roll, axis=1) if s >= w else jnp.pad(v, ((0, 0), (0, w - s), (0, 0), (0, 0)))
            cache = (kc, vc)
        else:
            pad = cache_capacity - s
            cax = A.cache_axes(cfg.n_kv_heads)
            cache = (constrain(jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0))), *cax),
                     constrain(jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0))), *cax))
    return x + o, cache


def _mlp_sublayer_full(x, blk, cfg: ArchConfig, plan: ExecPlan):
    h = L.rmsnorm(x, blk["ln2"], cfg.norm_eps, plan)
    if "moe" in blk:
        y, aux = M.moe_block(h, blk["moe"], cfg, plan)
        aux_vec = jnp.stack([aux.load_balance, aux.router_z])
    else:
        y = L.mlp(h, blk["mlp"], cfg.mlp_act, plan)
        aux_vec = jnp.zeros((2,), jnp.float32)
    y = constrain(y, "batch", "seq", None)
    return x + y, aux_vec


def _dense_block_full(x, blk, cfg, plan, positions, want_cache, cache_capacity):
    x, cache = _attn_sublayer_full(
        x, blk["attn"], blk["ln1"], cfg, plan, positions, want_cache, cache_capacity)
    x, aux = _mlp_sublayer_full(x, blk, cfg, plan)
    return x, aux, cache


def _rglru_sublayer_full(x, sub, cfg, plan, state=None):
    h = L.rmsnorm(x, sub["ln1"], cfg.norm_eps, plan)
    y, new_state = R.rglru_block(h, sub["rglru"], cfg, plan, state)
    x = x + constrain(y, "batch", "seq", None)
    h2 = L.rmsnorm(x, sub["ln2"], cfg.norm_eps, plan)
    x = x + L.mlp(h2, sub["mlp"], cfg.mlp_act, plan)
    return x, new_state


def _hybrid_macro_full(x, blk, cfg, plan, positions, want_cache):
    states: dict = {}
    cache = None
    for i, kind in enumerate(cfg.block_pattern):
        sub = blk[f"sub{i}"]
        if kind == "rglru":
            x, st = _rglru_sublayer_full(x, sub, cfg, plan)
            states[f"rglru{i}"] = {"h": st.h, "conv": st.conv}
        else:
            x, kv = _attn_sublayer_full(
                x, sub["attn"], sub["ln1"], cfg, plan, positions,
                want_cache, cfg.local_window)
            x, _ = _mlp_sublayer_full(x, sub, cfg, plan)
            if want_cache:
                cache = kv
    if not want_cache:
        states = {k: None for k in states}
    return x, states, cache


def _rwkv_block_full(x, blk, cfg, plan, state=None):
    p = blk["tm_cm"]
    h = L.layernorm(x, blk["ln1_s"], blk["ln1_b"], cfg.norm_eps)
    prev = W.RWKVState(state["wkv"], state["shift_tm"], state["shift_cm"]) if state else None
    y, wkv, last_tm = W.time_mix(h, p, cfg, plan, prev)
    x = x + constrain(y, "batch", "seq", None)
    h2 = L.layernorm(x, blk["ln2_s"], blk["ln2_b"], cfg.norm_eps)
    y2, last_cm = W.channel_mix(h2, p, cfg, plan, prev)
    x = x + y2
    return x, {"wkv": wkv, "shift_tm": last_tm, "shift_cm": last_cm}


# ---------------------------------------------------------------------------
# trunk forward (full-sequence)
# ---------------------------------------------------------------------------


def _cast_blocks(blocks, plan: ExecPlan):
    """Optionally cast float weights to the compute dtype BEFORE the layer
    scan, so per-layer FSDP all-gathers move bf16 instead of fp32 (halves
    the dominant collective term; grads still accumulate into fp32 masters
    through the differentiable cast)."""
    if plan.gather_dtype != "compute":
        return blocks
    dt = L.cdtype(plan)
    return jax.tree_util.tree_map(
        lambda w: w.astype(dt) if jnp.issubdtype(w.dtype, jnp.floating) else w,
        blocks)


def forward_full(params: dict, x: Array, cfg: ArchConfig, plan: ExecPlan,
                 positions: Array, want_cache: bool = False,
                 cache_capacity: int = 0) -> tuple[Array, Array, dict]:
    """x: (B,S,d) embedded inputs.  Returns (hidden, aux(2,), decode_caches)."""
    caches: dict = {}
    cache_capacity = cache_capacity or x.shape[1]
    params = dict(params)
    params["blocks"] = _cast_blocks(params["blocks"], plan)

    if cfg.family == "ssm":
        def body(carry, blk):
            h, st = _rwkv_block_full(carry, blk, cfg, plan)
            outs = st if want_cache else jnp.zeros((), jnp.float32)
            return h, outs
        body = _maybe_remat(body, plan)
        x, sts = jax.lax.scan(body, x, params["blocks"])
        if want_cache:
            caches["rwkv"] = sts
        return x, jnp.zeros((2,), jnp.float32), caches

    if cfg.family == "hybrid":
        pre_states = []
        for sub in params.get("pre_blocks", []):
            x, st = _rglru_sublayer_full(x, sub, cfg, plan)
            pre_states.append({"h": st.h, "conv": st.conv})

        def body(carry, blk):
            h, states, kv = _hybrid_macro_full(carry, blk, cfg, plan, positions, want_cache)
            outs = (states, kv) if want_cache else jnp.zeros((), jnp.float32)
            return h, outs
        body = _maybe_remat(body, plan)
        x, outs = jax.lax.scan(body, x, params["blocks"])
        if want_cache:
            states, kv = outs
            caches["macro_rglru"] = states
            caches["macro_kv"] = {"k": kv[0], "v": kv[1]}
            if pre_states:
                caches["pre_rglru"] = jax.tree_util.tree_map(
                    lambda *xs: jnp.stack(xs), *pre_states)
        return x, jnp.zeros((2,), jnp.float32), caches

    # dense / moe / vlm
    def body(carry, blk):
        h, aux, kv = _dense_block_full(
            carry, blk, cfg, plan, positions, want_cache, cache_capacity)
        outs = (aux, kv) if want_cache else aux
        return h, outs
    body = _maybe_remat(body, plan)
    x, outs = jax.lax.scan(body, x, params["blocks"])
    if want_cache:
        auxs, kv = outs
        caches["kv"] = {"k": kv[0], "v": kv[1]}
    else:
        auxs = outs
    return x, jnp.sum(auxs, axis=0), caches


# ---------------------------------------------------------------------------
# embeddings / head
# ---------------------------------------------------------------------------


def embed_inputs(params: dict, cfg: ArchConfig, plan: ExecPlan, tokens: Array,
                 patch_feats: Optional[Array] = None) -> Array:
    x = L.embed_tokens(tokens, params["embed"], plan, cfg.scale_embeddings)
    if cfg.vision_patches and patch_feats is not None:
        pj = params["projector"]
        dt = L.cdtype(plan)
        v = jax.nn.gelu(patch_feats.astype(dt) @ pj["vis_w1"].astype(dt)
                        + pj["vis_b1"].astype(dt), approximate=True)
        v = v @ pj["vis_w2"].astype(dt) + pj["vis_b2"].astype(dt)
        x = jnp.concatenate([v, x], axis=1)
    if cfg.family == "ssm":
        x = L.layernorm(x, params["embed_norm_s"], params["embed_norm_b"], cfg.norm_eps)
    return constrain(x, "batch", "seq", None)


def head_table(params: dict) -> Array:
    return params["embed"] if "lm_head" not in params else params["lm_head"]


def lm_logits(params: dict, cfg: ArchConfig, plan: ExecPlan, hidden: Array) -> Array:
    h = L.rmsnorm(hidden, params["final_norm"], cfg.norm_eps, plan)
    out = L.logits_from_hidden(h, head_table(params), plan, cfg.logit_softcap)
    return constrain(out, "batch", "seq", "vocab")


# ---------------------------------------------------------------------------
# loss (train step core)
# ---------------------------------------------------------------------------


def lm_loss(params: dict, batch: dict, cfg: ArchConfig, plan: ExecPlan) -> tuple[Array, dict]:
    tokens = batch["tokens"]
    labels = batch["labels"]
    patch = batch.get("patch_feats")
    frames = batch.get("frames")  # only whisper (handled in whisper.py)
    del frames
    x = embed_inputs(params, cfg, plan, tokens, patch)
    s_total = x.shape[1]
    positions = jnp.arange(s_total, dtype=jnp.int32)
    hidden, aux, _ = forward_full(params, x, cfg, plan, positions)
    # labels align with the token part (vlm: image prefix carries no loss)
    hidden = hidden[:, s_total - tokens.shape[1]:]
    hidden = L.rmsnorm(hidden, params["final_norm"], cfg.norm_eps, plan)
    mask = (labels >= 0).astype(jnp.float32)
    safe_labels = jnp.maximum(labels, 0)
    if plan.loss_impl == "chunked_vocab":
        nll = L.cross_entropy_chunked(hidden, head_table(params), safe_labels,
                                      plan, cfg.logit_softcap)
    else:
        logits = L.logits_from_hidden(hidden, head_table(params), plan, cfg.logit_softcap)
        logits = constrain(logits, "batch", "seq", "vocab")
        nll = L.cross_entropy_full(logits, safe_labels)
    ce = jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1.0)
    metrics = {"ce": ce}
    loss = ce
    if cfg.moe is not None:
        lb, z = aux[0] / cfg.n_layers, aux[1] / cfg.n_layers
        loss = loss + cfg.moe.aux_loss * lb + cfg.moe.router_z_loss * z
        metrics.update({"moe_lb": lb, "moe_z": z})
    metrics["loss"] = loss
    return loss, metrics


# ---------------------------------------------------------------------------
# prefill / decode
# ---------------------------------------------------------------------------


def prefill(params: dict, cfg: ArchConfig, plan: ExecPlan, tokens: Array,
            patch_feats: Optional[Array] = None,
            cache_capacity: int = 0) -> tuple[Array, dict]:
    """Returns (last-token logits, decode state)."""
    x = embed_inputs(params, cfg, plan, tokens, patch_feats)
    s_total = x.shape[1]
    positions = jnp.arange(s_total, dtype=jnp.int32)
    hidden, _, caches = forward_full(
        params, x, cfg, plan, positions, want_cache=True,
        cache_capacity=max(cache_capacity, s_total))
    logits = lm_logits(params, cfg, plan, hidden[:, -1:])
    state = dict(caches)
    state["cache_len"] = jnp.asarray(s_total, jnp.int32)
    return logits, state


def _dense_block_decode(x1, blk, kv, cache_len, cfg, plan):
    h = L.rmsnorm(x1, blk["ln1"], cfg.norm_eps, plan)
    pos = cache_len[None].astype(jnp.int32)
    q, k, v = A.project_qkv(h, blk["attn"], cfg, plan, pos)
    ring = cfg.attn_kind == "local"
    cache = A.cache_update(A.KVCache(kv["k"], kv["v"]), k, v, cache_len, ring)
    o = A.attend_decode(q, cache, cache_len + 1, cfg.local_window if ring else 0,
                        plan, ring)
    o = o.reshape(x1.shape[0], 1, -1) @ blk["attn"]["wo"].astype(L.cdtype(plan))
    x1 = x1 + o
    x1, _ = _mlp_sublayer_full(x1, blk, cfg, plan)
    return x1, {"k": cache.k, "v": cache.v}


def _rglru_sublayer_decode(x1, sub, st, cfg, plan):
    state = R.RGLRUState(st["h"], st["conv"])
    h = L.rmsnorm(x1, sub["ln1"], cfg.norm_eps, plan)
    y, new_state = R.rglru_block(h, sub["rglru"], cfg, plan, state)
    x1 = x1 + y
    h2 = L.rmsnorm(x1, sub["ln2"], cfg.norm_eps, plan)
    x1 = x1 + L.mlp(h2, sub["mlp"], cfg.mlp_act, plan)
    return x1, {"h": new_state.h, "conv": new_state.conv}


def _tree_index(tree, i):
    return jax.tree_util.tree_map(
        lambda a: jax.lax.dynamic_index_in_dim(a, i, 0, keepdims=False), tree)


def _tree_update(tree, sub, i):
    return jax.tree_util.tree_map(
        lambda a, s: jax.lax.dynamic_update_index_in_dim(a, s, i, 0), tree, sub)


def decode_step(params: dict, cfg: ArchConfig, plan: ExecPlan, token: Array,
                state: dict) -> tuple[Array, dict]:
    """token: (B,1) int32.  Returns (logits (B,1,V), new state).

    The stacked per-layer caches travel as scan CARRIES (indexed and
    written back per layer) instead of xs/ys: with input donation the
    while loop updates them in place — one cache-sized buffer live instead
    of three (measured: gemma decode_32k 34.8 GB -> fits).
    """
    cache_len = state["cache_len"]
    x1 = embed_inputs(params, cfg, plan, token, None)
    new_state: dict = {"cache_len": cache_len + 1}

    if cfg.family == "ssm":
        n_layers = jax.tree_util.tree_leaves(params["blocks"])[0].shape[0]

        def body(carry, blk_i):
            h, caches = carry
            blk, i = blk_i
            st = _tree_index(caches, i)
            h, new_st = _rwkv_block_full(h, blk, cfg, plan, state=st)
            return (h, _tree_update(caches, new_st, i)), None
        (x1, sts), _ = jax.lax.scan(
            body, (x1, state["rwkv"]),
            (params["blocks"], jnp.arange(n_layers)))
        new_state["rwkv"] = sts
    elif cfg.family == "hybrid":
        pre_states = []
        for i, sub in enumerate(params.get("pre_blocks", [])):
            st = jax.tree_util.tree_map(lambda a: a[i], state["pre_rglru"])
            x1, new_st = _rglru_sublayer_decode(x1, sub, st, cfg, plan)
            pre_states.append(new_st)
        if pre_states:
            new_state["pre_rglru"] = jax.tree_util.tree_map(
                lambda *xs: jnp.stack(xs), *pre_states)

        n_macro = jax.tree_util.tree_leaves(params["blocks"])[0].shape[0]

        def body(carry, blk_i):
            h, rg_all, kv_all = carry
            blk, i = blk_i
            rg_st = _tree_index(rg_all, i)
            kv = _tree_index(kv_all, i)
            new_rg: dict = {}
            new_kv = kv
            for j, kind in enumerate(cfg.block_pattern):
                sub = blk[f"sub{j}"]
                if kind == "rglru":
                    h, new_rg[f"rglru{j}"] = _rglru_sublayer_decode(
                        h, sub, rg_st[f"rglru{j}"], cfg, plan)
                else:
                    h, new_kv = _dense_block_decode(h, sub, kv, cache_len, cfg, plan)
            return (h, _tree_update(rg_all, new_rg, i),
                    _tree_update(kv_all, new_kv, i)), None
        (x1, rg_sts, kv_sts), _ = jax.lax.scan(
            body, (x1, state["macro_rglru"], state["macro_kv"]),
            (params["blocks"], jnp.arange(n_macro)))
        new_state["macro_rglru"] = rg_sts
        new_state["macro_kv"] = kv_sts
    else:
        n_layers = jax.tree_util.tree_leaves(params["blocks"])[0].shape[0]

        def body(carry, blk_i):
            h, kv_all = carry
            blk, i = blk_i
            kv = _tree_index(kv_all, i)
            h, new_kv = _dense_block_decode(h, blk, kv, cache_len, cfg, plan)
            return (h, _tree_update(kv_all, new_kv, i)), None
        (x1, kv_sts), _ = jax.lax.scan(
            body, (x1, state["kv"]), (params["blocks"], jnp.arange(n_layers)))
        new_state["kv"] = kv_sts

    logits = lm_logits(params, cfg, plan, x1)
    return logits, new_state
