"""RWKV-6 "Finch" block (arXiv:2404.05892): data-dependent decay time-mix +
squared-relu channel-mix, both with token-shift.

Per head (head dim D), state S in R^{DxD}:
    y_t = (S_{t-1} + (u * k_t) outer v_t)^T r_t
    S_t = diag(w_t) S_{t-1} + k_t outer v_t
with w_t = exp(-exp(w0 + lora_w(x_t))) in (0,1), data-dependent.

Region implementations (ExecPlan.wkv_impl):
* ``step``    — lax.scan over time (oracle; decode uses one step)
* ``chunked`` — scan over chunks; intra-chunk closed form with log-space
                decay ratios (all <= 1, numerically safe).  jnp twin of
                kernels/wkv6.py.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models import layers as L
from repro.models.plan import ExecPlan

Array = jax.Array
_LORA_R = 64       # decay lora rank
_DD_R = 32         # ddlerp lora rank


class RWKVState(NamedTuple):
    wkv: Array     # (B, H, Dk, Dv) recurrence state, fp32
    shift_tm: Array  # (B, d) previous token (time-mix)
    shift_cm: Array  # (B, d) previous token (channel-mix)


def rwkv_init(key, cfg: ArchConfig, dtype=jnp.float32) -> dict:
    d = cfg.d_model
    hd = cfg.rwkv_head_dim
    nh = d // hd
    ks = jax.random.split(key, 16)
    return {
        # time-mix
        "mu_base": jnp.full((d,), 0.5, dtype),
        "mu_rkvwg": jnp.full((5, d), 0.5, dtype),
        "dd_w1": L.dense_init(ks[0], (d, 5 * _DD_R), dtype=dtype),
        "dd_w2": (jax.random.normal(ks[1], (5, _DD_R, d)) * 0.01).astype(dtype),
        "wr": L.dense_init(ks[2], (d, d), dtype=dtype),
        "wk": L.dense_init(ks[3], (d, d), dtype=dtype),
        "wv": L.dense_init(ks[4], (d, d), dtype=dtype),
        "wg": L.dense_init(ks[5], (d, d), dtype=dtype),
        "wo": L.dense_init(ks[6], (d, d), dtype=dtype),
        "w0": jnp.full((d,), -6.0, dtype),  # decay bias: w ~ exp(-exp(-6)) ~ slow
        "w_lora_a": L.dense_init(ks[7], (d, _LORA_R), dtype=dtype),
        "w_lora_b": (jax.random.normal(ks[8], (_LORA_R, d)) * 0.01).astype(dtype),
        "u": (jax.random.normal(ks[9], (nh, hd)) * 0.1).astype(dtype),  # bonus
        "ln_x_scale": jnp.ones((d,), dtype),  # per-head groupnorm scale
        "ln_x_bias": jnp.zeros((d,), dtype),
        # channel-mix
        "cm_mu_k": jnp.full((d,), 0.5, dtype),
        "cm_mu_r": jnp.full((d,), 0.5, dtype),
        "cm_wk": L.dense_init(ks[10], (d, cfg.d_ff), dtype=dtype),
        "cm_wv": L.dense_init(ks[11], (cfg.d_ff, d), dtype=dtype),
        "cm_wr": L.dense_init(ks[12], (d, d), dtype=dtype),
    }


def _token_shift(x: Array, prev: Array | None) -> Array:
    """Returns x_{t-1} along axis=1; position 0 uses `prev` (or zeros)."""
    first = prev[:, None] if prev is not None else jnp.zeros_like(x[:, :1])
    return jnp.concatenate([first, x[:, :-1]], axis=1)


def _ddlerp(x: Array, sx: Array, p: dict) -> tuple[Array, ...]:
    """Finch data-dependent lerp: 5 mixed inputs for r,k,v,w,g."""
    dx = sx - x
    xxx = x + dx * p["mu_base"].astype(x.dtype)
    z = jnp.tanh(xxx @ p["dd_w1"].astype(x.dtype))
    z = z.reshape(*x.shape[:-1], 5, _DD_R)
    adj = jnp.einsum("...fr,frd->...fd", z, p["dd_w2"].astype(x.dtype))
    mix = p["mu_rkvwg"].astype(x.dtype) + adj  # (...,5,d)
    outs = tuple(x + dx * mix[..., i, :] for i in range(5))
    return outs  # xr, xk, xv, xw, xg


# ---------------------------------------------------------------------------
# wkv recurrence — step (oracle) and chunked implementations
# All inputs per head: r,k,v (B,S,H,D); log_w (B,S,H,D) <= 0; u (H,D).
# ---------------------------------------------------------------------------


def wkv_step_scan(r: Array, k: Array, v: Array, log_w: Array, u: Array,
                  s0: Array) -> tuple[Array, Array]:
    """Sequential oracle: y (B,S,H,Dv), final state (B,H,Dk,Dv)."""
    def step(s, rkvw):
        rt, kt, vt, lwt = rkvw  # (B,H,D)
        kv = jnp.einsum("bhk,bhv->bhkv", kt, vt)
        at = s + u[None, :, :, None] * kv
        y = jnp.einsum("bhk,bhkv->bhv", rt, at)
        s = jnp.exp(lwt)[..., None] * s + kv
        return s, y
    xs = tuple(a.transpose(1, 0, 2, 3) for a in (r, k, v, log_w))
    sT, ys = jax.lax.scan(step, s0, xs)
    return ys.transpose(1, 0, 2, 3), sT


def wkv_chunked(r: Array, k: Array, v: Array, log_w: Array, u: Array,
                s0: Array, chunk: int) -> tuple[Array, Array]:
    """Chunked parallel form, sharded per (batch, head) via shard_map.

    Heads are independent; (B*H) flattens into one leading dim sharded
    across the whole mesh (same scheme as flash attention), so the chunk
    scan runs fully local.  Falls back to unsharded when (B*H) does not
    divide the mesh."""
    from jax.sharding import PartitionSpec as P
    from repro.runtime.pspec import dividing_axes, local_map

    b, s, h, d = r.shape

    def flat(a):  # (B,S,H,D) -> (BH,S,D)
        return a.transpose(0, 2, 1, 3).reshape(b * h, s, d)

    rf, kf, vf, lwf = map(flat, (r, k, v, log_w))
    uf = jnp.broadcast_to(u[None], (b, h, d)).reshape(b * h, d)
    s0f = s0.reshape(b * h, d, d)

    axes = dividing_axes(b * h)
    if not axes:
        yf, sTf = _wkv_chunked_bh(rf, kf, vf, lwf, uf, s0f, chunk)
    else:
        spec = axes if len(axes) > 1 else axes[0]
        s3 = P(spec, None, None)
        s2 = P(spec, None)
        yf, sTf = local_map(
            lambda *a: _wkv_chunked_bh(*a, chunk), (s3,) * 4 + (s2, s3),
            (s3, s3), rf, kf, vf, lwf, uf, s0f)
    y = yf.reshape(b, h, s, d).transpose(0, 2, 1, 3)
    return y, sTf.reshape(b, h, d, d)


def _wkv_chunked_bh(r: Array, k: Array, v: Array, log_w: Array, u: Array,
                    s0: Array, chunk: int) -> tuple[Array, Array]:
    """Local chunked wkv on flattened (BH, S, D) operands.

    Within a chunk (length C), with cs_t = cumsum(log_w) inclusive:
      inter:  y_t += r_t . exp(cs_{t-1}) @ S_in            (decay from entry)
      intra:  y_t += sum_{s<t} (r_t . exp(cs_{t-1}-cs_s)) k_s  v_s
      bonus:  y_t += (r_t . u . k_t) v_t
      S_out = exp(cs_C) S_in + sum_s exp(cs_C - cs_s) k_s v_s
    All decay ratios have non-positive exponents -> exp <= 1, stable.
    """
    bh, s, d = r.shape
    c = min(chunk, s)
    if s % c != 0:
        return _wkv_step_bh(r, k, v, log_w, u, s0)
    n = s // c

    def reshape(a):
        return a.reshape(bh, n, c, d).transpose(1, 0, 2, 3)    # (n,BH,c,D)

    rc, kc, vc, lwc = map(reshape, (r, k, v, log_w))

    def body(s_in, rkvw):
        rt, kt, vt, lwt = rkvw                  # (BH,c,D)
        cs = jnp.cumsum(lwt, axis=1)            # inclusive cumsum
        cs_prev = cs - lwt                      # exclusive
        r_dec = rt * jnp.exp(cs_prev)
        y_inter = jnp.einsum("bck,bkv->bcv", r_dec, s_in)
        k_dec = kt * jnp.exp(-cs)
        scores = jnp.einsum("btk,bsk->bts", r_dec, k_dec)
        tri = jnp.tril(jnp.ones((c, c), bool), k=-1)
        scores = jnp.where(tri[None], scores, 0.0)
        y_intra = jnp.einsum("bts,bsv->btv", scores, vt)
        y_diag = jnp.sum(rt * u[:, None] * kt, axis=-1, keepdims=True) * vt
        y = y_inter + y_intra + y_diag
        cs_last = cs[:, -1:]                    # (BH,1,D)
        k_tail = kt * jnp.exp(cs_last - cs)
        s_new = jnp.exp(cs_last[:, 0])[..., None] * s_in + jnp.einsum(
            "bsk,bsv->bkv", k_tail, vt)
        return s_new, y

    sT, ys = jax.lax.scan(body, s0, (rc, kc, vc, lwc))
    return ys.transpose(1, 0, 2, 3).reshape(bh, s, d), sT


def _wkv_step_bh(r, k, v, log_w, u, s0):
    """3D step-scan fallback for ragged chunk splits."""
    def step(st, rkvw):
        rt, kt, vt, lwt = rkvw                  # (BH,D)
        kv = kt[:, :, None] * vt[:, None, :]
        y = jnp.einsum("bk,bkv->bv", rt, st + u[:, :, None] * kv)
        st = jnp.exp(lwt)[..., None] * st + kv
        return st, y
    xs = tuple(a.transpose(1, 0, 2) for a in (r, k, v, log_w))
    sT, ys = jax.lax.scan(step, s0, xs)
    return ys.transpose(1, 0, 2), sT


def _warn_exp_ratio_note() -> None:
    """The intra-chunk term uses exp(cs_prev_t)·exp(-cs_s) = exp(cs_prev_t - cs_s).

    Split as written it can overflow for strong decay; we therefore clamp
    log_w below and keep chunks short (<=128).  The Pallas kernel computes
    the fused difference directly.
    """


# ---------------------------------------------------------------------------
# full block
# ---------------------------------------------------------------------------


def _groupnorm_heads(y: Array, scale: Array, bias: Array, nh: int, eps: float = 64e-5) -> Array:
    b, s, d = y.shape
    yh = y.reshape(b, s, nh, d // nh).astype(jnp.float32)
    mu = jnp.mean(yh, axis=-1, keepdims=True)
    var = jnp.var(yh, axis=-1, keepdims=True)
    yh = (yh - mu) * jax.lax.rsqrt(var + eps)
    out = yh.reshape(b, s, d) * scale.astype(jnp.float32) + bias.astype(jnp.float32)
    return out


def time_mix(x: Array, p: dict, cfg: ArchConfig, plan: ExecPlan,
             state: RWKVState | None) -> tuple[Array, Array, Array]:
    """Returns (y, new_wkv_state, last_x)."""
    dt = L.cdtype(plan)
    b, s, d = x.shape
    hd = cfg.rwkv_head_dim
    nh = d // hd
    sx = _token_shift(x, state.shift_tm if state is not None else None)
    xr, xk, xv, xw, xg = _ddlerp(x, sx, p)
    rr = (xr @ p["wr"].astype(dt)).reshape(b, s, nh, hd).astype(jnp.float32)
    kk = (xk @ p["wk"].astype(dt)).reshape(b, s, nh, hd).astype(jnp.float32)
    vv = (xv @ p["wv"].astype(dt)).reshape(b, s, nh, hd).astype(jnp.float32)
    g = jax.nn.silu(xg @ p["wg"].astype(dt))
    w_pre = p["w0"].astype(jnp.float32) + (
        jnp.tanh(xw @ p["w_lora_a"].astype(dt)).astype(jnp.float32)
        @ p["w_lora_b"].astype(jnp.float32))
    log_w = -jnp.exp(jnp.clip(w_pre, -8.0, 2.0))  # <= 0, bounded for stability
    log_w = log_w.reshape(b, s, nh, hd)
    u = p["u"].astype(jnp.float32)
    s0 = state.wkv if state is not None else jnp.zeros((b, nh, hd, hd), jnp.float32)
    if plan.wkv_impl == "chunked":
        y, sT = wkv_chunked(rr, kk, vv, log_w, u, s0, plan.wkv_chunk)
    else:
        y, sT = wkv_step_scan(rr, kk, vv, log_w, u, s0)
    y = _groupnorm_heads(y.reshape(b, s, d), p["ln_x_scale"], p["ln_x_bias"], nh)
    out = (y.astype(dt) * g) @ p["wo"].astype(dt)
    return out, sT, x[:, -1]


def channel_mix(x: Array, p: dict, cfg: ArchConfig, plan: ExecPlan,
                state: RWKVState | None) -> tuple[Array, Array]:
    dt = L.cdtype(plan)
    sx = _token_shift(x, state.shift_cm if state is not None else None)
    dx = sx - x
    xk = x + dx * p["cm_mu_k"].astype(dt)
    xr = x + dx * p["cm_mu_r"].astype(dt)
    kk = jnp.square(jax.nn.relu(xk @ p["cm_wk"].astype(dt)))
    y = jax.nn.sigmoid(xr @ p["cm_wr"].astype(dt)) * (kk @ p["cm_wv"].astype(dt))
    return y, x[:, -1]
