"""Griffin / RecurrentGemma recurrent block: conv1d + RG-LRU gated recurrence.

RG-LRU (arXiv:2402.19427):
    r_t = sigmoid(W_a x_t)                    (recurrence gate, block-diag)
    i_t = sigmoid(W_x x_t)                    (input gate, block-diag)
    log a_t = -c * softplus(Lambda) * r_t     (c = 8)
    h_t = a_t * h_{t-1} + sqrt(1 - a_t^2) * (i_t * x_t)

Region implementations (ExecPlan.rglru_impl):
* ``step``    — lax.scan over time (reference/oracle; decode uses one step)
* ``assoc``   — lax.associative_scan (log-depth; offloaded path)
* ``chunked`` — outer scan over time chunks, assoc scan inside (the Pallas
                kernel's tiling; jnp twin of kernels/rglru_scan.py)
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models import layers as L
from repro.models.plan import ExecPlan

Array = jax.Array
_C = 8.0


class RGLRUState(NamedTuple):
    h: Array       # (B, d_rnn) recurrence state
    conv: Array    # (B, width-1, d_rnn) trailing conv inputs


def rglru_init(key, cfg: ArchConfig, dtype=jnp.float32) -> dict:
    d, dr = cfg.d_model, cfg.d_rnn_resolved
    nh = cfg.n_heads
    dh = dr // nh
    ks = jax.random.split(key, 7)
    return {
        "w_branch": L.dense_init(ks[0], (d, dr), dtype=dtype),   # gelu branch
        "w_in": L.dense_init(ks[1], (d, dr), dtype=dtype),       # recurrent branch
        "w_out": L.dense_init(ks[2], (dr, d), dtype=dtype),
        "w_conv": (jax.random.normal(ks[3], (cfg.conv1d_width, dr)) * 0.1).astype(dtype),
        "b_conv": jnp.zeros((dr,), dtype),
        # block-diagonal gates: (heads, dh, dh)
        "w_a": L.dense_init(ks[4], (nh, dh, dh), dtype=dtype),
        "b_a": jnp.zeros((dr,), dtype),
        "w_x": L.dense_init(ks[5], (nh, dh, dh), dtype=dtype),
        "b_x": jnp.zeros((dr,), dtype),
        "lam": (jax.random.uniform(ks[6], (dr,), minval=0.4, maxval=0.8)),  # Lambda init
    }


def _gates(x: Array, p: dict, cfg: ArchConfig) -> tuple[Array, Array]:
    """Block-diagonal gate projections.  x: (..., d_rnn)."""
    nh = cfg.n_heads
    shape = x.shape
    xh = x.reshape(*shape[:-1], nh, shape[-1] // nh)
    r = jnp.einsum("...hd,hde->...he", xh, p["w_a"].astype(x.dtype)).reshape(shape)
    i = jnp.einsum("...hd,hde->...he", xh, p["w_x"].astype(x.dtype)).reshape(shape)
    r = jax.nn.sigmoid(r.astype(jnp.float32) + p["b_a"].astype(jnp.float32))
    i = jax.nn.sigmoid(i.astype(jnp.float32) + p["b_x"].astype(jnp.float32))
    return r, i


def _coeffs(x: Array, p: dict, cfg: ArchConfig) -> tuple[Array, Array]:
    """Returns (log_a, b) with h_t = a_t h_{t-1} + b_t, all fp32."""
    r, i = _gates(x, p, cfg)
    log_a = -_C * jax.nn.softplus(p["lam"].astype(jnp.float32)) * r  # (...,dr) <= 0
    a2 = jnp.exp(2.0 * log_a)
    b = jnp.sqrt(jnp.maximum(1.0 - a2, 1e-12)) * (i * x.astype(jnp.float32))
    return log_a, b


# --- the three scan implementations ---------------------------------------


def _scan_step(log_a: Array, b: Array, h0: Array) -> tuple[Array, Array]:
    """(B,S,dr) coeffs -> (B,S,dr) states via per-step scan."""
    def step(h, ab):
        la, bt = ab
        h = jnp.exp(la) * h + bt
        return h, h
    hT, hs = jax.lax.scan(step, h0, (log_a.transpose(1, 0, 2), b.transpose(1, 0, 2)))
    return hs.transpose(1, 0, 2), hT


def _scan_assoc(log_a: Array, b: Array, h0: Array) -> tuple[Array, Array]:
    """Log-depth associative scan over the time axis (axis=1)."""
    def combine(c1, c2):
        la1, b1 = c1
        la2, b2 = c2
        return la1 + la2, jnp.exp(la2) * b1 + b2
    # fold h0 into the first step
    b = b.at[:, 0].add(jnp.exp(log_a[:, 0]) * h0)
    la_c, hs = jax.lax.associative_scan(combine, (log_a, b), axis=1)
    return hs, hs[:, -1]


def _scan_chunked(log_a: Array, b: Array, h0: Array, chunk: int) -> tuple[Array, Array]:
    bsz, s, dr = b.shape
    c = min(chunk, s)
    if s % c != 0:
        return _scan_assoc(log_a, b, h0)
    n = s // c

    def body(h, ab):
        la, bt = ab  # (B,c,dr)
        bt = bt.at[:, 0].add(jnp.exp(la[:, 0]) * h)
        def combine(c1, c2):
            la1, b1 = c1
            la2, b2 = c2
            return la1 + la2, jnp.exp(la2) * b1 + b2
        _, hs = jax.lax.associative_scan(combine, (la, bt), axis=1)
        return hs[:, -1], hs

    hT, hs = jax.lax.scan(
        body, h0,
        (log_a.reshape(bsz, n, c, dr).transpose(1, 0, 2, 3),
         b.reshape(bsz, n, c, dr).transpose(1, 0, 2, 3)))
    return hs.transpose(1, 0, 2, 3).reshape(bsz, s, dr), hT


def rglru_scan(log_a: Array, b: Array, h0: Array, plan: ExecPlan) -> tuple[Array, Array]:
    """Channels are independent: run the scan fully local under shard_map
    (B over data, channels over model) so SPMD never reshards mid-scan."""
    from jax.sharding import PartitionSpec as P
    from repro.runtime.pspec import dividing_axes, local_map

    def run(la, bb, h):
        if plan.rglru_impl == "assoc":
            return _scan_assoc(la, bb, h)
        if plan.rglru_impl == "chunked":
            return _scan_chunked(la, bb, h, plan.rglru_chunk)
        return _scan_step(la, bb, h)

    bsz, _, dr = log_a.shape
    b_axes = dividing_axes(bsz, (("pod", "data"), ("data",)))
    d_axes = dividing_axes(dr, (("model",),))
    if not b_axes and not d_axes:
        return run(log_a, b, h0)
    bspec = b_axes if len(b_axes) > 1 else (b_axes[0] if b_axes else None)
    dspec = d_axes[0] if d_axes else None
    s3 = P(bspec, None, dspec)
    s2 = P(bspec, dspec)
    return local_map(run, (s3, s3, s2), (s3, s2), log_a, b, h0)


# --- conv1d (causal depthwise) ---------------------------------------------


def conv1d_causal(x: Array, w: Array, bias: Array, prefix: Array | None = None) -> Array:
    """x: (B,S,dr); w: (width, dr).  prefix: (B,width-1,dr) carried state."""
    width = w.shape[0]
    if prefix is None:
        prefix = jnp.zeros((x.shape[0], width - 1, x.shape[2]), x.dtype)
    xp = jnp.concatenate([prefix, x], axis=1)
    out = jnp.zeros_like(x, dtype=jnp.float32)
    for i in range(width):
        out = out + xp[:, i:i + x.shape[1]].astype(jnp.float32) * w[width - 1 - i].astype(jnp.float32)
    return (out + bias.astype(jnp.float32)).astype(x.dtype)


# --- full block -------------------------------------------------------------


def rglru_block(x: Array, p: dict, cfg: ArchConfig, plan: ExecPlan,
                state: RGLRUState | None = None) -> tuple[Array, RGLRUState]:
    """x: (B,S,d_model) -> (B,S,d_model), new state (for decode continuation)."""
    dt = L.cdtype(plan)
    bsz = x.shape[0]
    dr = cfg.d_rnn_resolved
    branch = jax.nn.gelu(x @ p["w_branch"].astype(dt), approximate=True)
    u_raw = x @ p["w_in"].astype(dt)
    prefix = state.conv if state is not None else None
    u = conv1d_causal(u_raw, p["w_conv"], p["b_conv"], prefix)
    log_a, b = _coeffs(u, p, cfg)
    h0 = state.h if state is not None else jnp.zeros((bsz, dr), jnp.float32)
    hs, hT = rglru_scan(log_a, b, h0, plan)
    y = (hs.astype(dt) * branch) @ p["w_out"].astype(dt)
    width = cfg.conv1d_width
    old_prefix = state.conv if state is not None else jnp.zeros((bsz, width - 1, dr), dt)
    new_conv = jnp.concatenate([old_prefix.astype(dt), u_raw], axis=1)[:, -(width - 1):]
    return y, RGLRUState(hT, new_conv)
