from repro.models.api import Model, build_model
from repro.models.plan import ExecPlan, OFFLOAD_PLAN, REFERENCE_PLAN

__all__ = ["Model", "build_model", "ExecPlan", "OFFLOAD_PLAN", "REFERENCE_PLAN"]
