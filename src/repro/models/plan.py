"""Execution plan: the knob surface the offload planner searches.

The paper encodes "which loop runs on the accelerator" as a binary gene.  Our
TPU analogue: every *offloadable region* of a model has a reference (``ref``)
implementation and one or more accelerated implementations (fused/chunked jnp
rewrite on any backend; Pallas kernel when running on real TPU).  An
:class:`ExecPlan` pins one implementation per region plus the transfer-
placement knobs; the GA in ``repro.core`` mutates plans through their binary
gene encoding (see ``core/genes.py``).
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Any


@dataclass(frozen=True)
class ExecPlan:
    # --- per-region implementation selection (the paper's loop genes) ------
    attn_impl: str = "naive"        # naive | chunked (flash-style online softmax)
    norm_impl: str = "ref"          # ref | fused
    mlp_impl: str = "ref"           # ref | fused
    qkv_fused: bool = False         # fuse q,k,v projections into one matmul
    rglru_impl: str = "step"        # step | assoc | chunked
    wkv_impl: str = "step"          # step | chunked
    moe_impl: str = "dense_onehot"  # dense_onehot | scatter_ep
    loss_impl: str = "full"         # full | chunked_vocab

    # --- tiling (BlockSpec analogue for the jnp paths) ----------------------
    attn_q_chunk: int = 512
    attn_kv_chunk: int = 1024
    rglru_chunk: int = 256
    wkv_chunk: int = 64
    loss_vocab_chunk: int = 32_768

    # --- memory / transfer knobs (the paper's CPU<->GPU transfer hoisting) --
    remat: str = "dots"             # none | dots | full
    gather_mode: str = "per_layer"  # per_layer | hoisted  (FSDP all-gather placement)
    donate_state: bool = True       # donate params/cache buffers (kills D2H copies)
    microbatch: int = 1             # grad-accumulation splits of the global batch
    gather_dtype: str = "param"     # param | compute: cast weights BEFORE the
                                    # per-layer FSDP gather (bf16 halves traffic)

    # --- misc -----------------------------------------------------------------
    compute_dtype: str = "bfloat16"

    def replace(self, **kw: Any) -> "ExecPlan":
        return dataclasses.replace(self, **kw)

    # Regions that have an accelerated alternative, in canonical order.  This
    # is what the gene encoder enumerates (core/genes.py); order is part of
    # the framework ABI so genomes are reproducible.
    OFFLOAD_SITES: tuple[tuple[str, str, str], ...] = (
        # (field, ref_value, offload_value)
        ("attn_impl", "naive", "chunked"),
        ("norm_impl", "ref", "fused"),
        ("mlp_impl", "ref", "fused"),
        ("qkv_fused", False, True),
        ("rglru_impl", "step", "assoc"),
        ("wkv_impl", "step", "chunked"),
        ("moe_impl", "dense_onehot", "scatter_ep"),
        ("loss_impl", "full", "chunked_vocab"),
        ("remat", "none", "dots"),
        ("gather_mode", "hoisted", "per_layer"),
    )

    # Full implementation menu per offload site where the executors ship
    # more than the (ref, offload) pair.  Index order is the gene contract
    # (`Destination.impl_index`: 0 = reference, 1 = primary accelerated,
    # 2+ = extra variants), so a multi-destination chromosome selects WHICH
    # implementation runs, not just whether the site is offloaded.  Sites
    # absent here keep their binary OFFLOAD_SITES pair (genes clamp).
    SITE_VARIANTS = {
        "rglru_impl": ("step", "assoc", "chunked"),   # models/rglru.py
        "remat": ("none", "dots", "full"),            # models/transformer.py
    }


REFERENCE_PLAN = ExecPlan(
    attn_impl="naive",
    norm_impl="ref",
    mlp_impl="ref",
    qkv_fused=False,
    rglru_impl="step",
    wkv_impl="step",
    moe_impl="dense_onehot",
    loss_impl="full",
    remat="none",
    gather_mode="hoisted",
)

# The all-offload plan: every region on its accelerated implementation.
OFFLOAD_PLAN = ExecPlan(
    attn_impl="chunked",
    norm_impl="fused",
    mlp_impl="fused",
    qkv_fused=True,
    rglru_impl="assoc",
    wkv_impl="chunked",
    moe_impl="scatter_ep",
    loss_impl="chunked_vocab",
    remat="dots",
    gather_mode="per_layer",
)
