"""Mixture-of-Experts block (olmoe 64e/top-8, llama4-scout 16e/top-1 + shared).

Two region implementations (ExecPlan.moe_impl):

* ``dense_onehot`` — reference: every token runs through every expert, the
  top-k one-hot gate zeroes the rest.  Numerically equals the dispatched
  path with infinite capacity; E-times the FLOPs (the "CPU path").
* ``scatter_ep``   — production: top-k routing, capacity-limited scatter into
  per-expert (E, C, d) buffers, batched expert matmuls, weighted combine.
  Expert dim shards over the "model"/"expert" mesh axis (EP).
"""
from __future__ import annotations

from typing import NamedTuple, Optional

import jax
import numpy as np
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models import layers as L
from repro.models.plan import ExecPlan

Array = jax.Array


class MoEAux(NamedTuple):
    load_balance: Array  # scalar
    router_z: Array      # scalar


def moe_init(key, cfg: ArchConfig, dtype=jnp.float32) -> dict:
    e = cfg.moe
    d, ff = cfg.d_model, (e.d_ff_expert or cfg.d_ff)
    ks = jax.random.split(key, 5)
    p = {
        "w_router": L.dense_init(ks[0], (d, e.n_experts), dtype=jnp.float32),
        "w_gate": L.dense_init(ks[1], (e.n_experts, d, ff), dtype=dtype),
        "w_up": L.dense_init(ks[2], (e.n_experts, d, ff), dtype=dtype),
        "w_down": L.dense_init(ks[3], (e.n_experts, ff, d), in_axis=-2, dtype=dtype),
    }
    if e.n_shared_experts:
        p["shared"] = L.mlp_init(ks[4], d, ff * e.n_shared_experts, dtype=dtype)
    return p


def _route(x2d: Array, p: dict, cfg: ArchConfig) -> tuple[Array, Array, MoEAux]:
    """Router: returns (gates (T,k), expert idx (T,k), aux losses)."""
    e = cfg.moe
    logits = (x2d.astype(jnp.float32) @ p["w_router"])  # (T,E)
    probs = jax.nn.softmax(logits, axis=-1)
    gates, idx = jax.lax.top_k(probs, e.top_k)  # (T,k)
    gates = gates / jnp.maximum(jnp.sum(gates, axis=-1, keepdims=True), 1e-9)
    # Switch-style load-balance loss + z-loss
    density = jnp.mean(jax.nn.one_hot(idx, e.n_experts), axis=(0, 1))  # (E,)
    density_prob = jnp.mean(probs, axis=0)
    lb = e.n_experts * jnp.sum(density * density_prob)
    z = jnp.mean(jnp.square(jax.nn.logsumexp(logits, axis=-1)))
    return gates, idx, MoEAux(lb, z)


# ---------------------------------------------------------------------------
# reference: dense one-hot
# ---------------------------------------------------------------------------


def moe_dense(x2d: Array, p: dict, cfg: ArchConfig, plan: ExecPlan) -> tuple[Array, MoEAux]:
    e = cfg.moe
    dt = L.cdtype(plan)
    gates, idx, aux = _route(x2d, p, cfg)
    # (T, E) combined gate matrix (zero outside top-k)
    onehot = jax.nn.one_hot(idx, e.n_experts, dtype=jnp.float32)  # (T,k,E)
    combine = jnp.einsum("tk,tke->te", gates, onehot).astype(dt)
    # every token through every expert
    g = jnp.einsum("td,edf->tef", x2d, p["w_gate"].astype(dt))
    u = jnp.einsum("td,edf->tef", x2d, p["w_up"].astype(dt))
    h = L._act(g, cfg.mlp_act if cfg.mlp_act != "relu_sq" else "silu") * u
    y = jnp.einsum("tef,efd->ted", h, p["w_down"].astype(dt))
    out = jnp.einsum("ted,te->td", y, combine)
    return out + _shared(x2d, p, cfg, plan), aux


# ---------------------------------------------------------------------------
# production: capacity-limited scatter dispatch (EP)
# ---------------------------------------------------------------------------


def moe_scatter(x2d: Array, p: dict, cfg: ArchConfig, plan: ExecPlan) -> tuple[Array, MoEAux]:
    e = cfg.moe
    dt = L.cdtype(plan)
    t, d = x2d.shape
    gates, idx, aux = _route(x2d, p, cfg)

    n = t * e.top_k
    cap = int(max(1, (t * e.top_k / e.n_experts) * e.capacity_factor))
    e_flat = idx.reshape(-1)                         # (N,)
    tok_flat = jnp.repeat(jnp.arange(t), e.top_k)    # (N,)
    gate_flat = gates.reshape(-1)

    # within-expert rank via sort (dropless up to capacity)
    order = jnp.argsort(e_flat)
    sorted_e = e_flat[order]
    counts = jnp.bincount(e_flat, length=e.n_experts)
    starts = jnp.cumsum(counts) - counts
    rank_sorted = jnp.arange(n) - starts[sorted_e]
    rank = jnp.zeros((n,), jnp.int32).at[order].set(rank_sorted.astype(jnp.int32))

    keep = rank < cap

    # 2-D scatter into (E, C, d); out-of-capacity rows drop (token dropping).
    xb = jnp.zeros((e.n_experts, cap, d), dt)
    xb = xb.at[e_flat, rank].set(x2d[tok_flat].astype(dt), mode="drop")
    xb = pspec_constrain_experts(xb)

    # batched expert FFN: (E, C, d) x (E, d, ff)
    g = jnp.einsum("ecd,edf->ecf", xb, p["w_gate"].astype(dt))
    u = jnp.einsum("ecd,edf->ecf", xb, p["w_up"].astype(dt))
    h = L._act(g, cfg.mlp_act if cfg.mlp_act != "relu_sq" else "silu") * u
    yb = jnp.einsum("ecf,efd->ecd", h, p["w_down"].astype(dt))
    yb = pspec_constrain_experts(yb)

    # combine: gather back and weight
    rank_c = jnp.clip(rank, 0, cap - 1)
    gathered = jnp.where(keep[:, None], yb[e_flat, rank_c], 0.0)
    weighted = gathered * gate_flat[:, None].astype(dt)
    out = jnp.zeros((t, d), dt).at[tok_flat].add(weighted)
    return out + _shared(x2d, p, cfg, plan), aux


def pspec_constrain_experts(xb: Array) -> Array:
    from repro.runtime.pspec import constrain
    return constrain(xb, "experts", None, None)


def _shared(x2d: Array, p: dict, cfg: ArchConfig, plan: ExecPlan) -> Array:
    if "shared" not in p:
        return jnp.zeros((), L.cdtype(plan))
    return L.mlp(x2d, p["shared"], cfg.mlp_act if cfg.mlp_act != "relu_sq" else "silu", plan)


# ---------------------------------------------------------------------------
# shard_map EP: per-shard local dispatch + all_to_all over the expert axis.
# Tokens shard over the whole mesh; each shard routes its own tokens into
# (E, C_loc, d) buffers, all_to_all swaps expert-major <-> shard-major,
# local experts run batched matmuls, all_to_all returns, combine locally.
# FSDP'd expert weights are all-gathered explicitly inside (the per-layer
# gather — the paper's transfer-hoisting knob, made explicit).
# ---------------------------------------------------------------------------


def _moe_ep_body(x_loc, wr, wg, wu, wd, *, cfg: ArchConfig, plan: ExecPlan,
                 t_axes: tuple, msize: int):
    e = cfg.moe
    dt = L.cdtype(plan)
    tl, d = x_loc.shape
    # FSDP gathers (weights enter sharded over "data" on their d/ff dims)
    wr = jax.lax.all_gather(wr, "data", axis=0, tiled=True)     # (d, E)
    wg = jax.lax.all_gather(wg, "data", axis=1, tiled=True)     # (E_loc, d, ff)
    wu = jax.lax.all_gather(wu, "data", axis=1, tiled=True)
    wd = jax.lax.all_gather(wd, "data", axis=2, tiled=True)     # (E_loc, ff, d)

    logits = x_loc.astype(jnp.float32) @ wr                      # (Tl, E)
    probs = jax.nn.softmax(logits, axis=-1)
    gates, idx = jax.lax.top_k(probs, e.top_k)
    gates = gates / jnp.maximum(jnp.sum(gates, axis=-1, keepdims=True), 1e-9)

    n = tl * e.top_k
    cap = int(max(1, (tl * e.top_k / e.n_experts) * e.capacity_factor))
    e_flat = idx.reshape(-1)
    tok_flat = jnp.repeat(jnp.arange(tl), e.top_k)
    gate_flat = gates.reshape(-1)

    order = jnp.argsort(e_flat)
    sorted_e = e_flat[order]
    counts = jnp.bincount(e_flat, length=e.n_experts)
    starts = jnp.cumsum(counts) - counts
    rank_sorted = jnp.arange(n) - starts[sorted_e]
    rank = jnp.zeros((n,), jnp.int32).at[order].set(rank_sorted.astype(jnp.int32))
    keep = rank < cap

    buf = jnp.zeros((e.n_experts, cap, d), dt)
    buf = buf.at[e_flat, rank].set(x_loc[tok_flat].astype(dt), mode="drop")

    # expert-major <-> shard-major swap (EP all_to_all over "model")
    xb = jax.lax.all_to_all(buf, "model", split_axis=0, concat_axis=1,
                            tiled=True)                          # (E_loc, m*C, d)
    g = jnp.einsum("ecd,edf->ecf", xb, wg.astype(dt))
    u = jnp.einsum("ecd,edf->ecf", xb, wu.astype(dt))
    h = L._act(g, cfg.mlp_act if cfg.mlp_act != "relu_sq" else "silu") * u
    yb = jnp.einsum("ecf,efd->ecd", h, wd.astype(dt))
    yb = jax.lax.all_to_all(yb, "model", split_axis=1, concat_axis=0,
                            tiled=True)                          # (E, C, d)

    rank_c = jnp.clip(rank, 0, cap - 1)
    gathered = jnp.where(keep[:, None], yb[e_flat, rank_c], 0.0)
    y = jnp.zeros((tl, d), dt).at[tok_flat].add(
        gathered * gate_flat[:, None].astype(dt))

    # aux losses (global means via pmean over every token axis)
    density = jnp.mean(jax.nn.one_hot(idx, e.n_experts), axis=(0, 1))
    density_prob = jnp.mean(probs, axis=0)
    lb = e.n_experts * jnp.sum(
        jax.lax.pmean(density, t_axes) * jax.lax.pmean(density_prob, t_axes))
    z = jax.lax.pmean(
        jnp.mean(jnp.square(jax.nn.logsumexp(logits, axis=-1))), t_axes)
    return y, lb, z


def moe_scatter_ep_sharded(x2d: Array, p: dict, cfg: ArchConfig,
                           plan: ExecPlan) -> Optional[tuple[Array, MoEAux]]:
    """shard_map EP path; returns None when the mesh doesn't apply."""
    from jax.sharding import PartitionSpec as P
    from repro.runtime.pspec import current_rules, dividing_axes, axis_rules

    rules = current_rules()
    if rules is None:
        return None
    mesh = rules.mesh
    msize = mesh.shape.get("model", 1)
    if msize <= 1 or "data" not in mesh.shape:
        return None
    if cfg.moe.n_experts % msize != 0:
        return None
    t = x2d.shape[0]
    t_axes = dividing_axes(t, (("pod", "data", "model"), ("data", "model")))
    if "model" not in t_axes:
        return None
    tl = t // int(np.prod([mesh.shape[a] for a in t_axes]))
    if tl < cfg.moe.n_experts:  # degenerate local dispatch
        return None

    import functools
    body = functools.partial(_moe_ep_body, cfg=cfg, plan=plan,
                             t_axes=t_axes, msize=msize)

    def inner(x_loc, wr, wg, wu, wd):
        with axis_rules(None):
            return body(x_loc, wr, wg, wu, wd)

    tspec = P(t_axes, None)
    from repro.runtime.pspec import shard_map_compat
    y, lb, z = shard_map_compat(
        inner, mesh=mesh,
        in_specs=(tspec, P("data", None), P("model", "data", None),
                  P("model", "data", None), P("model", None, "data")),
        out_specs=(tspec, P(), P()),
    )(x2d, p["w_router"], p["w_gate"], p["w_up"], p["w_down"])
    return y, MoEAux(lb, z)


def moe_block(x: Array, p: dict, cfg: ArchConfig, plan: ExecPlan) -> tuple[Array, MoEAux]:
    """x: (B,S,d) -> (B,S,d), aux."""
    b, s, d = x.shape
    x2d = x.reshape(b * s, d)
    if plan.moe_impl == "scatter_ep":
        out = moe_scatter_ep_sharded(x2d, p, cfg, plan)
        if out is not None:
            y, aux = out
            y = y + _shared(x2d, p, cfg, plan)
        else:
            y, aux = moe_scatter(x2d, p, cfg, plan)
    else:
        y, aux = moe_dense(x2d, p, cfg, plan)
    return y.reshape(b, s, d), aux
