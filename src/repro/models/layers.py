"""Shared neural layers: norms, rotary embeddings, gated MLPs, embeddings.

Every layer has a ``ref`` implementation (plain jnp, the "CPU path" of the
paper) and, where profitable, a ``fused`` implementation (the "offloaded"
path — a fused-jnp rewrite on CPU/dry-run, a Pallas kernel on real TPU; see
``repro.kernels``).  Implementation choice comes from the :class:`ExecPlan`.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.plan import ExecPlan

Array = jax.Array


def cdtype(plan: ExecPlan):
    return jnp.dtype(plan.compute_dtype)


# ---------------------------------------------------------------------------
# initializers
# ---------------------------------------------------------------------------


def dense_init(key, shape, in_axis: int = -2, dtype=jnp.float32) -> Array:
    fan_in = shape[in_axis]
    std = 1.0 / np.sqrt(fan_in)
    return (jax.random.truncated_normal(key, -2.0, 2.0, shape) * std).astype(dtype)


def embed_init(key, shape, dtype=jnp.float32) -> Array:
    return (jax.random.normal(key, shape) * 0.02).astype(dtype)


# ---------------------------------------------------------------------------
# RMSNorm
# ---------------------------------------------------------------------------


def rmsnorm_ref(x: Array, scale: Array, eps: float) -> Array:
    """Reference: upcast, normalize, scale (separate ops)."""
    xf = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
    normed = xf * jax.lax.rsqrt(var + eps)
    return (normed * (1.0 + scale.astype(jnp.float32))).astype(x.dtype)


def rmsnorm_fused(x: Array, scale: Array, eps: float) -> Array:
    """Fused formulation (single-pass; Pallas kernel `kernels/rmsnorm.py` on TPU).

    Numerically identical to the reference — one fused expression lets XLA
    emit a single loop; on TPU the pattern DB swaps in the Pallas kernel.
    """
    xf = x.astype(jnp.float32)
    inv = jax.lax.rsqrt(jnp.mean(xf * xf, axis=-1, keepdims=True) + eps)
    return (xf * inv * (1.0 + scale.astype(jnp.float32))).astype(x.dtype)


def rmsnorm(x: Array, scale: Array, eps: float, plan: ExecPlan) -> Array:
    if plan.norm_impl == "fused":
        return rmsnorm_fused(x, scale, eps)
    return rmsnorm_ref(x, scale, eps)


def layernorm(x: Array, scale: Array, bias: Array, eps: float) -> Array:
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.mean(jnp.square(xf - mu), axis=-1, keepdims=True)
    y = (xf - mu) * jax.lax.rsqrt(var + eps)
    return (y * scale.astype(jnp.float32) + bias.astype(jnp.float32)).astype(x.dtype)


# ---------------------------------------------------------------------------
# Rotary position embeddings
# ---------------------------------------------------------------------------


def rope_freqs(head_dim: int, theta: float) -> Array:
    exponent = jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim
    return 1.0 / (theta**exponent)  # (head_dim/2,)


def apply_rope(x: Array, positions: Array, theta: float) -> Array:
    """x: (..., S, H, D); positions: (..., S)."""
    if theta <= 0:
        return x
    d = x.shape[-1]
    freqs = rope_freqs(d, theta)  # (D/2,)
    angles = positions[..., :, None, None].astype(jnp.float32) * freqs  # (...,S,1,D/2)
    sin, cos = jnp.sin(angles), jnp.cos(angles)
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# Gated MLP (SwiGLU / GeGLU)
# ---------------------------------------------------------------------------


def _act(x: Array, kind: str) -> Array:
    if kind == "silu":
        return jax.nn.silu(x)
    if kind == "gelu":
        return jax.nn.gelu(x, approximate=True)
    if kind == "relu_sq":
        return jnp.square(jax.nn.relu(x))
    raise ValueError(kind)


def mlp_init(key, d_model: int, d_ff: int, dtype=jnp.float32) -> dict:
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "w_gate": dense_init(k1, (d_model, d_ff), dtype=dtype),
        "w_up": dense_init(k2, (d_model, d_ff), dtype=dtype),
        "w_down": dense_init(k3, (d_ff, d_model), dtype=dtype),
    }


def _ff_constrain(h: Array) -> Array:
    """Pin the (..., ff) hidden to TP-column sharding so XLA gathers the
    (small) w_down weight, never the (huge) activation.  Rank-agnostic:
    (B,S,ff) for dense layers, (T,ff) for the shared-expert path."""
    from repro.runtime.pspec import constrain
    axes = ("batch",) + (None,) * (h.ndim - 2) + ("tensor",)
    return constrain(h, *axes)


def mlp_ref(x: Array, p: dict, act: str, plan: ExecPlan) -> Array:
    """Reference: three separate matmuls."""
    dt = cdtype(plan)
    g = _ff_constrain(x @ p["w_gate"].astype(dt))
    u = _ff_constrain(x @ p["w_up"].astype(dt))
    return _ff_constrain(_act(g, act) * u) @ p["w_down"].astype(dt)


def mlp_fused(x: Array, p: dict, act: str, plan: ExecPlan) -> Array:
    """Fused: gate+up as ONE matmul (halves weight re-reads; MXU-friendly)."""
    dt = cdtype(plan)
    wgu = jnp.concatenate([p["w_gate"], p["w_up"]], axis=1).astype(dt)
    gu = x @ wgu
    g, u = jnp.split(gu, 2, axis=-1)
    return _ff_constrain(_act(_ff_constrain(g), act) * _ff_constrain(u)) \
        @ p["w_down"].astype(dt)


def mlp(x: Array, p: dict, act: str, plan: ExecPlan) -> Array:
    if plan.mlp_impl == "fused":
        return mlp_fused(x, p, act, plan)
    return mlp_ref(x, p, act, plan)


# ---------------------------------------------------------------------------
# Embedding + logits
# ---------------------------------------------------------------------------


def embed_tokens(tokens: Array, table: Array, plan: ExecPlan, scale: bool) -> Array:
    x = jnp.take(table, tokens, axis=0).astype(cdtype(plan))
    if scale:
        x = x * jnp.asarray(np.sqrt(table.shape[1]), x.dtype)
    return x


def logits_from_hidden(h: Array, table: Array, plan: ExecPlan, softcap: float) -> Array:
    out = h @ table.T.astype(cdtype(plan))
    if softcap > 0:
        out = jnp.tanh(out / softcap) * softcap
    return out


def cross_entropy_full(logits: Array, labels: Array) -> Array:
    """Reference loss: materialize full (B,S,V) fp32 log-softmax.

    Returns per-token nll (B,S); caller applies the loss mask.
    """
    lp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
    return -jnp.take_along_axis(lp, labels[..., None], axis=-1)[..., 0]


def cross_entropy_chunked(h: Array, table: Array, labels: Array, plan: ExecPlan,
                          softcap: float) -> Array:
    """Memory-lean loss: never materialize fp32 (B,S,V).

    Computes logsumexp and the label logit by scanning vocab chunks; peak
    live memory is (B,S,chunk) instead of (B,S,V).  This is the "offloaded"
    loss region.  The hidden states are sequence-sharded over "model" so the
    per-chunk logits tensor shards too.
    """
    from repro.runtime.pspec import constrain
    h = constrain(h, "batch", "seq_sp", None)
    labels = constrain(labels, "batch", "seq_sp")
    v = table.shape[0]
    chunk = min(plan.loss_vocab_chunk, v)
    n_chunks = -(-v // chunk)
    pad_v = n_chunks * chunk
    tbl = jnp.pad(table, ((0, pad_v - v), (0, 0))) if pad_v != v else table
    tbl = tbl.reshape(n_chunks, chunk, table.shape[1])

    def body(carry, tchunk_i):
        m, s, lbl_logit, idx = carry
        tchunk, ci = tchunk_i
        lg = (h @ tchunk.T.astype(h.dtype)).astype(jnp.float32)  # (B,S,chunk)
        if softcap > 0:
            lg = jnp.tanh(lg / softcap) * softcap
        # mask padding columns
        col = ci * chunk + jnp.arange(chunk)
        lg = jnp.where(col[None, None, :] < v, lg, -jnp.inf)
        new_m = jnp.maximum(m, jnp.max(lg, axis=-1))
        s = s * jnp.exp(m - new_m) + jnp.sum(jnp.exp(lg - new_m[..., None]), axis=-1)
        # pick up the label logit if it lives in this chunk
        rel = labels - ci * chunk
        in_chunk = (rel >= 0) & (rel < chunk)
        picked = jnp.take_along_axis(lg, jnp.clip(rel, 0, chunk - 1)[..., None], axis=-1)[..., 0]
        lbl_logit = jnp.where(in_chunk, picked, lbl_logit)
        return (new_m, s, lbl_logit, idx), None

    b, s_len = labels.shape
    init = (
        jnp.full((b, s_len), -jnp.inf, jnp.float32),
        jnp.zeros((b, s_len), jnp.float32),
        jnp.zeros((b, s_len), jnp.float32),
        0,
    )
    (m, ssum, lbl_logit, _), _ = jax.lax.scan(
        body, init, (tbl, jnp.arange(n_chunks)))
    lse = m + jnp.log(ssum)
    return lse - lbl_logit  # per-token nll (B,S); caller applies the mask
