"""Whisper-small backbone: transformer encoder-decoder.

The conv/mel frontend is a STUB per the assignment: ``input_specs()`` feeds
precomputed frame embeddings (B, encoder_seq, d_model).  Positions are
sinusoidal (computed on the fly, so any decoder length works).  Decoder
blocks: causal self-attention + cross-attention to the encoder output + MLP.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig
from repro.models import attention as A
from repro.models import layers as L
from repro.models.plan import ExecPlan
from repro.models.transformer import _maybe_remat, _stack_init
from repro.runtime.pspec import constrain

Array = jax.Array


def sinusoid_positions(s: int, d: int, offset=0) -> Array:
    pos = jnp.arange(s, dtype=jnp.float32) + offset
    inv = jnp.exp(-jnp.arange(0, d, 2, dtype=jnp.float32) / d * np.log(10000.0))
    ang = pos[:, None] * inv[None, :]
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)


def _enc_block_init(key, cfg: ArchConfig, dtype) -> dict:
    k1, k2 = jax.random.split(key)
    return {
        "ln1": jnp.zeros((cfg.d_model,), dtype),
        "ln2": jnp.zeros((cfg.d_model,), dtype),
        "attn": A.attn_init(k1, cfg, dtype=dtype),
        "mlp": L.mlp_init(k2, cfg.d_model, cfg.d_ff, dtype=dtype),
    }


def _dec_block_init(key, cfg: ArchConfig, dtype) -> dict:
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "ln1": jnp.zeros((cfg.d_model,), dtype),
        "ln_x": jnp.zeros((cfg.d_model,), dtype),
        "ln2": jnp.zeros((cfg.d_model,), dtype),
        "attn": A.attn_init(k1, cfg, dtype=dtype),
        "xattn": A.attn_init(k3, cfg, dtype=dtype),
        "mlp": L.mlp_init(k2, cfg.d_model, cfg.d_ff, dtype=dtype),
    }


def init_params(cfg: ArchConfig, rng: jax.Array, dtype=jnp.float32) -> dict:
    ke, kd, kt = jax.random.split(rng, 3)
    return {
        "embed": L.embed_init(kt, (cfg.vocab, cfg.d_model), dtype),
        "final_norm": jnp.zeros((cfg.d_model,), dtype),
        "enc_final_norm": jnp.zeros((cfg.d_model,), dtype),
        "enc_blocks": _stack_init(ke, cfg.n_encoder_layers,
                                  lambda k: _enc_block_init(k, cfg, dtype)),
        "blocks": _stack_init(kd, cfg.n_layers,
                              lambda k: _dec_block_init(k, cfg, dtype)),
    }


# ---------------------------------------------------------------------------
# encoder
# ---------------------------------------------------------------------------


def encode(params: dict, cfg: ArchConfig, plan: ExecPlan, frames: Array) -> Array:
    """frames: (B, T_enc, d) stub embeddings -> (B, T_enc, d)."""
    dt = L.cdtype(plan)
    t_enc = frames.shape[1]
    x = frames.astype(dt) + sinusoid_positions(t_enc, cfg.d_model).astype(dt)
    x = constrain(x, "batch", "seq", None)
    positions = jnp.arange(t_enc, dtype=jnp.int32)

    def body(carry, blk):
        h = L.rmsnorm(carry, blk["ln1"], cfg.norm_eps, plan)
        q, k, v = A.project_qkv(h, blk["attn"], cfg, plan, positions)
        o = A.attend(q, k, v, positions, positions, causal=False,
                     attn_kind="full", window=0, plan=plan)
        o = o.reshape(*carry.shape[:2], -1) @ blk["attn"]["wo"].astype(dt)
        x1 = carry + constrain(o, "batch", "seq", None)
        h2 = L.rmsnorm(x1, blk["ln2"], cfg.norm_eps, plan)
        return x1 + L.mlp(h2, blk["mlp"], cfg.mlp_act, plan), jnp.zeros((), jnp.float32)

    body = _maybe_remat(body, plan)
    x, _ = jax.lax.scan(body, x, params["enc_blocks"])
    return L.rmsnorm(x, params["enc_final_norm"], cfg.norm_eps, plan)


# ---------------------------------------------------------------------------
# decoder
# ---------------------------------------------------------------------------


def _dec_block_full(x, blk, enc_out, cfg, plan, positions, want_cache, cache_capacity):
    dt = L.cdtype(plan)
    b, s, _ = x.shape
    enc_pos = jnp.arange(enc_out.shape[1], dtype=jnp.int32)
    # self attention
    h = L.rmsnorm(x, blk["ln1"], cfg.norm_eps, plan)
    q, k, v = A.project_qkv(h, blk["attn"], cfg, plan, positions)
    o = A.attend(q, k, v, positions, positions, causal=True,
                 attn_kind="full", window=0, plan=plan)
    x = x + (o.reshape(b, s, -1) @ blk["attn"]["wo"].astype(dt))
    # cross attention
    hx = L.rmsnorm(x, blk["ln_x"], cfg.norm_eps, plan)
    qx = A.project_q(hx, blk["xattn"], cfg, plan, positions)
    kx, vx = A.project_kv(enc_out, blk["xattn"], cfg, plan, enc_pos)
    ox = A.attend(qx, kx, vx, positions, enc_pos, causal=False,
                  attn_kind="full", window=0, plan=plan)
    x = x + (ox.reshape(b, s, -1) @ blk["xattn"]["wo"].astype(dt))
    # mlp
    h2 = L.rmsnorm(x, blk["ln2"], cfg.norm_eps, plan)
    x = x + L.mlp(h2, blk["mlp"], cfg.mlp_act, plan)
    cache = None
    if want_cache:
        pad = cache_capacity - s
        cax = A.cache_axes(cfg.n_kv_heads)
        cache = {
            "k": constrain(jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0))), *cax),
            "v": constrain(jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0))), *cax),
            "xk": constrain(kx, *cax),
            "xv": constrain(vx, *cax),
        }
    return x, cache


def decoder_forward(params, cfg, plan, tokens, enc_out, want_cache=False,
                    cache_capacity: int = 0):
    dt = L.cdtype(plan)
    s = tokens.shape[1]
    cache_capacity = cache_capacity or s
    x = L.embed_tokens(tokens, params["embed"], plan, False)
    x = x + sinusoid_positions(s, cfg.d_model).astype(dt)
    x = constrain(x, "batch", "seq", None)
    positions = jnp.arange(s, dtype=jnp.int32)

    def body(carry, blk):
        h, cache = _dec_block_full(carry, blk, enc_out, cfg, plan, positions,
                                   want_cache, cache_capacity)
        return h, (cache if want_cache else jnp.zeros((), jnp.float32))

    body = _maybe_remat(body, plan)
    x, caches = jax.lax.scan(body, x, params["blocks"])
    return x, (caches if want_cache else None)


def lm_loss(params: dict, batch: dict, cfg: ArchConfig, plan: ExecPlan):
    enc_out = encode(params, cfg, plan, batch["frames"])
    hidden, _ = decoder_forward(params, cfg, plan, batch["tokens"], enc_out)
    hidden = L.rmsnorm(hidden, params["final_norm"], cfg.norm_eps, plan)
    labels = batch["labels"]
    mask = (labels >= 0).astype(jnp.float32)
    safe = jnp.maximum(labels, 0)
    if plan.loss_impl == "chunked_vocab":
        nll = L.cross_entropy_chunked(hidden, params["embed"], safe, plan, 0.0)
    else:
        logits = L.logits_from_hidden(hidden, params["embed"], plan, 0.0)
        logits = constrain(logits, "batch", "seq", "vocab")
        nll = L.cross_entropy_full(logits, safe)
    ce = jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1.0)
    return ce, {"ce": ce, "loss": ce}


def prefill(params: dict, cfg: ArchConfig, plan: ExecPlan, tokens: Array,
            frames: Array, cache_capacity: int = 0):
    enc_out = encode(params, cfg, plan, frames)
    hidden, caches = decoder_forward(params, cfg, plan, tokens, enc_out,
                                     want_cache=True,
                                     cache_capacity=cache_capacity or tokens.shape[1])
    h = L.rmsnorm(hidden[:, -1:], params["final_norm"], cfg.norm_eps, plan)
    logits = L.logits_from_hidden(h, params["embed"], plan, 0.0)
    state = {"dec": caches, "cache_len": jnp.asarray(tokens.shape[1], jnp.int32)}
    return logits, state


def decode_step(params: dict, cfg: ArchConfig, plan: ExecPlan, token: Array,
                state: dict):
    dt = L.cdtype(plan)
    cache_len = state["cache_len"]
    b = token.shape[0]
    x1 = L.embed_tokens(token, params["embed"], plan, False)
    x1 = x1 + sinusoid_positions(1, cfg.d_model, offset=cache_len).astype(dt)
    from repro.models.transformer import _tree_index, _tree_update
    n_layers = jax.tree_util.tree_leaves(params["blocks"])[0].shape[0]

    def body(carry, blk_i):
        x, caches = carry
        blk, i = blk_i
        kv = _tree_index(caches, i)
        pos = cache_len[None].astype(jnp.int32)
        h = L.rmsnorm(x, blk["ln1"], cfg.norm_eps, plan)
        q, k, v = A.project_qkv(h, blk["attn"], cfg, plan, pos)
        cache = A.cache_update(A.KVCache(kv["k"], kv["v"]), k, v, cache_len, False)
        o = A.attend_decode(q, cache, cache_len + 1, 0, plan, False)
        x = x + (o.reshape(b, 1, -1) @ blk["attn"]["wo"].astype(dt))
        hx = L.rmsnorm(x, blk["ln_x"], cfg.norm_eps, plan)
        qx = A.project_q(hx, blk["xattn"], cfg, plan, pos)
        xcache = A.KVCache(kv["xk"], kv["xv"])
        ox = A.attend_decode(qx, xcache, jnp.asarray(kv["xk"].shape[1], jnp.int32),
                             0, plan, False)
        x = x + (ox.reshape(b, 1, -1) @ blk["xattn"]["wo"].astype(dt))
        h2 = L.rmsnorm(x, blk["ln2"], cfg.norm_eps, plan)
        x = x + L.mlp(h2, blk["mlp"], cfg.mlp_act, plan)
        new_kv = {"k": cache.k, "v": cache.v, "xk": kv["xk"], "xv": kv["xv"]}
        return (x, _tree_update(caches, new_kv, i)), None

    (x1, caches), _ = jax.lax.scan(
        body, (x1, state["dec"]), (params["blocks"], jnp.arange(n_layers)))
    h = L.rmsnorm(x1, params["final_norm"], cfg.norm_eps, plan)
    logits = L.logits_from_hidden(h, params["embed"], plan, 0.0)
    return logits, {"dec": caches, "cache_len": cache_len + 1}
