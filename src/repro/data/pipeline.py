"""Data pipeline: deterministic, shardable token streams with host-side
prefetch (the H2D staging whose hoisting the paper optimizes).

Two sources:
  * :class:`SyntheticLMDataset` — seeded Zipf-ish token stream; infinite,
    reproducible, no files.  Used by smoke tests and the example drivers.
  * :class:`TokenFileDataset` — memory-mapped uint16/uint32 binary token
    file (the "real data" path), sequence-packed.

The :class:`Batcher` draws per-host shards deterministically from
(step, host_id) so restarts resume exactly (checkpointed `step` is the only
state), and keeps a one-batch prefetch buffer so host data prep overlaps the
device step — compute/transfer overlap at the pipeline level.
"""
from __future__ import annotations

import threading
import queue as _queue
from dataclasses import dataclass
from typing import Iterator, Optional

import numpy as np

from repro.configs.base import ArchConfig


@dataclass(frozen=True)
class DataConfig:
    seq_len: int = 512
    global_batch: int = 8
    vocab: int = 32_000
    seed: int = 1234
    pack_docs: bool = True
    path: Optional[str] = None    # set -> TokenFileDataset


class SyntheticLMDataset:
    """Deterministic synthetic LM stream: Zipf unigrams + short-range
    repetition structure (so loss curves actually bend)."""

    def __init__(self, cfg: DataConfig):
        self.cfg = cfg
        ranks = np.arange(1, cfg.vocab + 1, dtype=np.float64)
        probs = 1.0 / ranks
        self.probs = probs / probs.sum()

    def batch(self, step: int, host_id: int = 0, n_hosts: int = 1) -> dict:
        cfg = self.cfg
        rng = np.random.default_rng(
            np.random.SeedSequence([cfg.seed, step, host_id]))
        b = cfg.global_batch // n_hosts
        toks = rng.choice(cfg.vocab, size=(b, cfg.seq_len + 1), p=self.probs)
        # inject copy structure: second half repeats the first with noise
        half = cfg.seq_len // 2
        noise = rng.random((b, half + 1)) < 0.1
        src = toks[:, :half + 1]
        toks[:, half:] = np.where(noise, toks[:, half:], src[:, : toks.shape[1] - half])
        toks = toks.astype(np.int32)
        return {"tokens": toks[:, :-1], "labels": toks[:, 1:]}


class TokenFileDataset:
    """Memory-mapped binary token file -> packed (tokens, labels) batches."""

    def __init__(self, cfg: DataConfig):
        self.cfg = cfg
        assert cfg.path, "TokenFileDataset needs cfg.path"
        raw = np.memmap(cfg.path, dtype=np.uint16, mode="r")
        self.tokens = raw
        self.n = len(raw)

    def batch(self, step: int, host_id: int = 0, n_hosts: int = 1) -> dict:
        cfg = self.cfg
        b = cfg.global_batch // n_hosts
        span = cfg.seq_len + 1
        rng = np.random.default_rng(
            np.random.SeedSequence([cfg.seed, step, host_id]))
        starts = rng.integers(0, self.n - span, size=b)
        toks = np.stack([self.tokens[s:s + span] for s in starts]).astype(np.int32)
        toks = np.minimum(toks, cfg.vocab - 1)
        return {"tokens": toks[:, :-1], "labels": toks[:, 1:]}


def make_dataset(cfg: DataConfig):
    return TokenFileDataset(cfg) if cfg.path else SyntheticLMDataset(cfg)


class Batcher:
    """Prefetching iterator: host prep of batch t+1 overlaps device step t."""

    def __init__(self, dataset, start_step: int = 0, host_id: int = 0,
                 n_hosts: int = 1, prefetch: int = 2,
                 extras: Optional[dict] = None):
        self.dataset = dataset
        self.step = start_step
        self.host_id = host_id
        self.n_hosts = n_hosts
        self.extras = extras or {}
        self._q: _queue.Queue = _queue.Queue(maxsize=prefetch)
        self._stop = threading.Event()
        self._thread = threading.Thread(target=self._worker, daemon=True)
        self._thread.start()

    def _worker(self):
        s = self.step
        while not self._stop.is_set():
            batch = self.dataset.batch(s, self.host_id, self.n_hosts)
            batch.update(self.extras)
            try:
                self._q.put((s, batch), timeout=0.5)
                s += 1
            except _queue.Full:
                continue

    def __iter__(self) -> Iterator[tuple[int, dict]]:
        return self

    def __next__(self) -> tuple[int, dict]:
        return self._q.get()

    def close(self):
        self._stop.set()
        try:
            while True:
                self._q.get_nowait()
        except _queue.Empty:
            pass
        self._thread.join(timeout=2.0)
