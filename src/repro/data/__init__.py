from repro.data.pipeline import (DataConfig, SyntheticLMDataset, TokenFileDataset,
                                 make_dataset, Batcher)

__all__ = ["DataConfig", "SyntheticLMDataset", "TokenFileDataset",
           "make_dataset", "Batcher"]
